//! Batch-parallel serving engine over the lane-major bit-plane
//! datapath (DESIGN.md §Perf).
//!
//! [`BatchedEngine`] packs up to [`MAX_LANES`] clips into the `u64`
//! bit-lanes of a [`LaneFrame`] stream and runs every stateful layer
//! through [`SpidrCore::run_layer_lanes`]: one im2col walk, one union
//! address stream, and one contiguous CIM-row sweep per batch instead
//! of per clip. Zero-skipping becomes "skip cells whose lane word is
//! 0", so host dispatch overhead is amortized across the batch while
//! lane `b`'s Vmems, output spikes, and telemetry stay **bit-exact**
//! against a per-clip [`ReferenceEngine`] run of clip `b`
//! (`prop_batched_bit_identical_per_lane`).
//!
//! The serving tier selects it like its siblings: set
//! [`ServerConfig::batch`](super::server::ServerConfig) /
//! [`PoolConfig::batch`](super::pool::PoolConfig) and
//! [`FunctionalEngine::from_config`](super::pipeline::FunctionalEngine)
//! builds one; the single-engine server and the pool workers then
//! drain their inboxes through [`Engine::infer_batch`] in batches of
//! up to [`BatchConfig::capacity`] clips.

use crate::error::{Error, Result};
use crate::sim::config::SimConfig;
use crate::sim::{LaneBank, SpidrCore};
use crate::snn::layer::LayerKind;
use crate::snn::network::{pool_step_lanes, Network, StepTelemetry};
use crate::snn::spikes::{LaneFrame, SpikePlane, MAX_LANES};

use super::server::Engine;

/// Configuration of the batched bit-plane engine, sibling of
/// `PipelineConfig`/`DistributedConfig` (carried as an `Option` by
/// `ServerConfig` and `PoolConfig` to select the engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchConfig {
    /// Desired clips per batch; clamped to `1..=`[`MAX_LANES`] (the
    /// `u64` lane-word width) by [`BatchConfig::capacity`].
    pub max_lanes: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_lanes: MAX_LANES,
        }
    }
}

impl BatchConfig {
    /// A batch of up to `max_lanes` clips.
    pub fn with_lanes(max_lanes: usize) -> Self {
        BatchConfig { max_lanes }
    }

    /// Effective clips per batch: `max_lanes` clamped to the lane-word
    /// width (`1..=`[`MAX_LANES`]).
    pub fn capacity(&self) -> usize {
        self.max_lanes.clamp(1, MAX_LANES)
    }
}

/// The batch-parallel functional serving engine: up to [`MAX_LANES`]
/// clips per inference call, packed into bit-plane lanes and swept
/// through the CIM rows once per batch. Per-clip results are
/// bit-identical to [`ReferenceEngine`](super::server::ReferenceEngine)
/// lane by lane; per-lane [`StepTelemetry`] for the most recent batch
/// is kept on the engine.
#[derive(Debug, Clone)]
pub struct BatchedEngine {
    network: Network,
    core: SpidrCore,
    cfg: BatchConfig,
    /// Per-lane, per-timestep telemetry of the most recent batch.
    telemetry: Vec<Vec<StepTelemetry>>,
}

impl BatchedEngine {
    /// Build an engine around a workload. Validates up front that
    /// every stateful layer's fan-in is mappable onto the core
    /// (`select_mode`), so serving never fails mid-batch on a layer
    /// the chip could not host.
    pub fn new(network: Network, cfg: BatchConfig) -> Result<Self> {
        if network.layers.is_empty() {
            return Err(Error::config("empty network"));
        }
        let core = SpidrCore::new(SimConfig {
            precision: network.precision,
            ..SimConfig::default()
        });
        for layer in network.stateful_layers() {
            core.select_mode(layer.fan_in())?;
        }
        Ok(BatchedEngine {
            network,
            core,
            cfg,
            telemetry: Vec::new(),
        })
    }

    /// The workload this engine serves.
    pub fn network(&self) -> &Network {
        &self.network
    }

    /// Effective clips per batch (the serving tier's drain limit).
    pub fn capacity(&self) -> usize {
        self.cfg.capacity()
    }

    /// Per-lane telemetry of the most recent batch: entry `b` holds
    /// clip `b`'s per-timestep [`StepTelemetry`], bit-identical to
    /// what [`Network::run`] reports for that clip alone.
    pub fn telemetry(&self) -> &[Vec<StepTelemetry>] {
        &self.telemetry
    }

    /// Run one batch of clips (clip `b` → bit-lane `b`); output `b` is
    /// clip `b`'s final accumulator bank, bit-identical to a per-clip
    /// run. All clips must share the network's input shape and one
    /// timestep count; at most [`Self::capacity`] clips per call
    /// ([`Engine::infer_batch`] chunks larger batches).
    pub fn infer_lanes(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<Vec<i32>>> {
        // One CIM sweep per batch; attributes to the serving tier's
        // bound trace (the batch anchor clip). Inert unless sampled.
        let _tspan = crate::obs::trace::span("lane_batch");
        if clips.len() > self.cfg.capacity() {
            return Err(Error::config(format!(
                "batch of {} clips exceeds the configured lane capacity {}",
                clips.len(),
                self.cfg.capacity()
            )));
        }
        let mut frames = LaneFrame::pack_clips(clips)?;
        let lanes = clips.len();
        let timesteps = frames.len();
        self.telemetry = vec![vec![StepTelemetry::default(); timesteps]; lanes];
        if timesteps == 0 {
            // An empty clip leaves every Vmem bank zeroed, exactly as
            // the reference engine's reset-then-no-steps path does.
            let (m, k) = self.network.out_shape()?;
            return Ok(vec![vec![0; m * k]; lanes]);
        }
        let mut last_bank: Option<LaneBank> = None;
        for layer in &self.network.layers {
            match layer.kind {
                LayerKind::Pool => {
                    frames = frames.iter().map(|f| pool_step_lanes(layer, f)).collect();
                }
                LayerKind::Conv | LayerKind::Fc => {
                    for (t, f) in frames.iter().enumerate() {
                        let cells = f.plane().len() as u64;
                        for (b, spikes) in f.lane_counts().into_iter().enumerate() {
                            self.telemetry[b][t].layer_input_spikes.push(spikes);
                            self.telemetry[b][t].layer_input_cells.push(cells);
                        }
                    }
                    let (m, k) = layer.vmem_shape()?;
                    let mut bank = LaneBank::zeros(m, k, lanes);
                    let (out, _) = self.core.run_layer_lanes(layer, &frames, &mut bank)?;
                    frames = out;
                    last_bank = Some(bank);
                }
            }
        }
        let bank = last_bank.ok_or_else(|| Error::config("network has no stateful layers"))?;
        Ok((0..lanes)
            .map(|b| bank.lane_mat(b).as_slice().to_vec())
            .collect())
    }
}

impl Engine for BatchedEngine {
    type Output = Vec<i32>;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Vec<i32>> {
        Ok(self
            .infer_lanes(&[clip])?
            .pop()
            .expect("one clip in, one output out"))
    }

    fn max_batch(&self) -> usize {
        self.cfg.capacity()
    }

    fn infer_batch(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<Vec<i32>>> {
        let mut out = Vec::with_capacity(clips.len());
        for chunk in clips.chunks(self.cfg.capacity()) {
            out.extend(self.infer_lanes(chunk)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::ReferenceEngine;
    use crate::prop::{check, Gen};
    use crate::quant::Precision;
    use crate::snn::layer::{NeuronConfig, ResetMode};
    use crate::snn::network::NetworkBuilder;
    use crate::snn::tensor::Mat;

    fn rand_mat(g: &mut Gen, rows: usize, cols: usize) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, g.i32_in(-7..=7));
            }
        }
        m
    }

    /// A random spiking network: 1–3 hidden conv layers (random
    /// channels, thresholds, leaks, reset modes), an optional pool,
    /// and an accumulate FC readout — the same family the pipeline
    /// equivalence property uses.
    fn random_network(g: &mut Gen) -> Network {
        let in_ch = 1 + g.index(2);
        let h = 4 + 2 * g.index(3);
        let w = 4 + 2 * g.index(3);
        let hidden = 1 + g.index(3);
        let pool_after = g.index(hidden + 1); // == hidden means "none"
        let mut b = NetworkBuilder::new("prop-batch", Precision::W4V7, 3, (in_ch, h, w));
        for i in 0..hidden {
            let (c, _, _) = b.shape();
            let out_ch = 2 + g.index(5);
            let neuron = NeuronConfig {
                theta: 1 + g.i32_in(0..=6),
                leak: g.i32_in(0..=2),
                leaky: g.chance(0.5),
                reset: if g.chance(0.5) {
                    ResetMode::Soft
                } else {
                    ResetMode::Hard
                },
            };
            let wm = rand_mat(g, c * 9, out_ch);
            b = b.conv3x3(out_ch, wm, neuron, false).unwrap();
            if i == pool_after {
                b = b.pool(2, 2);
            }
        }
        let (c, hh, ww) = b.shape();
        let out = 2 + g.index(3);
        let wm = rand_mat(g, c * hh * ww, out);
        b.fc(out, wm, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    /// One random clip; with probability 0.15 it is entirely silent,
    /// exercising the all-zero-lane edge of the union stream.
    fn random_clip(g: &mut Gen, net: &Network, t: usize) -> Vec<SpikePlane> {
        let (c, h, w) = net.layers[0].in_shape;
        let density = if g.chance(0.15) {
            0.0
        } else {
            0.1 + g.f64() * 0.4
        };
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(c, h, w);
                for i in 0..p.len() {
                    if g.chance(density) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    /// Satellite: every lane of the batched engine — outputs *and*
    /// per-step telemetry — is bit-identical to a per-clip
    /// [`ReferenceEngine`] / [`Network::run`] of that lane's clip,
    /// across random networks, batch sizes `1..=64`, densities
    /// (all-zero lanes included), and timestep counts. Saturate-mode
    /// equivalence is pinned at the layer level by
    /// `prop_batched_layer_matches_per_clip` (the reference executor
    /// is wrap-only).
    #[test]
    fn prop_batched_bit_identical_per_lane() {
        check("batched_bit_identical_per_lane", 8, |g| {
            let net = random_network(g);
            let t = 1 + g.index(3);
            let lanes = 1 + g.index(MAX_LANES);
            let clips: Vec<Vec<SpikePlane>> =
                (0..lanes).map(|_| random_clip(g, &net, t)).collect();
            let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();

            let mut batched = BatchedEngine::new(net.clone(), BatchConfig::default()).unwrap();
            let outs = batched.infer_lanes(&refs).unwrap();
            assert_eq!(outs.len(), lanes);

            let mut reference = ReferenceEngine::new(net.clone()).unwrap();
            for (b, clip) in clips.iter().enumerate() {
                let want = reference.infer(clip).unwrap();
                if outs[b] != want {
                    return false;
                }
                let mut state = net.init_state().unwrap();
                let tel = net.run(clip, &mut state).unwrap();
                if batched.telemetry()[b] != tel {
                    return false;
                }
            }
            true
        });
    }

    /// Degenerate batch of one: `infer` on the batched engine equals
    /// the reference engine clip for clip.
    #[test]
    fn batch_of_one_matches_reference_infer() {
        let mut g = Gen::new(7);
        let net = random_network(&mut g);
        let clip = random_clip(&mut g, &net, 4);
        let mut batched = BatchedEngine::new(net.clone(), BatchConfig::with_lanes(1)).unwrap();
        let mut reference = ReferenceEngine::new(net).unwrap();
        assert_eq!(batched.capacity(), 1);
        assert_eq!(
            batched.infer(&clip).unwrap(),
            reference.infer(&clip).unwrap()
        );
    }

    /// `infer_batch` chunks a stream larger than the lane capacity and
    /// still matches the reference per clip; `infer_lanes` itself
    /// rejects over-capacity batches.
    #[test]
    fn infer_batch_chunks_beyond_capacity() {
        let mut g = Gen::new(21);
        let net = random_network(&mut g);
        let clips: Vec<Vec<SpikePlane>> =
            (0..7).map(|_| random_clip(&mut g, &net, 3)).collect();
        let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();

        let mut batched = BatchedEngine::new(net.clone(), BatchConfig::with_lanes(3)).unwrap();
        assert!(batched.infer_lanes(&refs).is_err(), "7 clips > capacity 3");
        let outs = batched.infer_batch(&refs).unwrap();

        let mut reference = ReferenceEngine::new(net).unwrap();
        for (b, clip) in clips.iter().enumerate() {
            assert_eq!(outs[b], reference.infer(clip).unwrap(), "clip {b}");
        }
    }

    #[test]
    fn capacity_clamps_to_the_lane_word() {
        assert_eq!(BatchConfig::with_lanes(0).capacity(), 1);
        assert_eq!(BatchConfig::with_lanes(200).capacity(), MAX_LANES);
        assert_eq!(BatchConfig::default().capacity(), MAX_LANES);
    }

    /// An unmappable fan-in is rejected at construction, not mid-batch.
    #[test]
    fn unmappable_fan_in_rejected_at_build() {
        let net = NetworkBuilder::new("too-wide", Precision::W4V7, 2, (3, 20, 20))
            .fc(2, Mat::zeros(1200, 2), NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap();
        assert!(BatchedEngine::new(net, BatchConfig::default()).is_err());
    }
}
