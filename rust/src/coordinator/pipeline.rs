//! Timestep-pipelined layer-group execution (DESIGN.md §Pipeline).
//!
//! The sequential executors step a clip layer by layer: every layer of
//! timestep `t` finishes before timestep `t+1` starts, so single-clip
//! latency is the *sum* of the per-layer costs. But layer group `g` at
//! timestep `t` only depends on group `g−1` at `t` — the dependence
//! structure the paper exploits with inter-timestep pipelining and
//! asynchronous handshaking between chained units. This module lifts
//! that mechanism to whole layer groups:
//!
//! ```text
//! frames ─► stage 0 ═►═ stage 1 ═►═ … ═►═ stage G-1 ─► output Vmems
//!          (group 0)   (group 1)          (group G-1)
//!                bounded spike-frame channels
//! ```
//!
//! Each layer group from `plan_layer_groups` runs on its own stage
//! thread, owning its group's slice of the partitioned
//! [`NetworkState`]. Adjacent stages are connected by **bounded**
//! spike-frame channels — the software analogue of the chip's
//! handshaking FIFOs: a full channel blocks the upstream stage
//! (backpressure), an empty one blocks the downstream stage
//! (starvation), and frames are never dropped. Timestep `t` of group
//! `g` overlaps with timestep `t+1` of group `g−1`, so steady-state
//! clip latency approaches `(G−1)·t_stage + T·t_stage` with `t_stage`
//! the slowest group's per-timestep cost — the *max* over stages
//! instead of the sum over layers.
//!
//! Every stage calls the same [`Network::step_group`] the sequential
//! paths use, so pipelined execution is **bit-identical** to
//! [`Network::run`] and to `MultiCoreScheduler::run_network_clip`
//! (`prop_pipeline_bit_identical_to_reference`).

use crate::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::net::coordinator::{DistributedConfig, DistributedEngine};
use crate::snn::network::{GroupSpan, Network, NetworkState, StepTelemetry};
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

use super::batch::{BatchConfig, BatchedEngine};
use super::metrics::StageMetrics;
use super::scheduler::plan_layer_groups;
use super::server::{Engine, ReferenceEngine};

/// Configuration of the staged layer-group pipeline, sibling of
/// `ServerConfig`/`PoolConfig` (both of which carry an
/// `Option<PipelineConfig>` to select the pipelined functional
/// engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipelineConfig {
    /// Desired stage count; clamped to the network's stateful-layer
    /// count (`plan_layer_groups` never returns an empty group).
    pub stages: usize,
    /// Bounded spike-frame channel depth between adjacent stages (the
    /// handshaking FIFO depth; a full channel stalls the producer).
    pub channel_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            stages: 4,
            channel_depth: 2,
        }
    }
}

impl PipelineConfig {
    /// A pipeline of `stages` stages with the default channel depth.
    pub fn with_stages(stages: usize) -> Self {
        PipelineConfig {
            stages,
            ..PipelineConfig::default()
        }
    }
}

/// What one stage thread hands back when its clip share completes.
struct StageOutcome {
    metrics: StageMetrics,
    /// This group's telemetry fragment, one entry per timestep.
    telemetry: Vec<StepTelemetry>,
    /// Completion time relative to the pipeline epoch (drain
    /// accounting happens in the parent, which knows the full wall).
    finished_at: Duration,
}

/// Secondary error a stage reports when a neighbour exited early and
/// tore the channel down; the parent prefers the neighbour's primary
/// error over this one.
fn channel_torn_down(stage: usize, dir: &str) -> Error {
    Error::Runtime(format!(
        "pipeline stage {stage}: {dir} stage channel closed early"
    ))
}

fn is_channel_teardown(e: &Error) -> bool {
    matches!(e, Error::Runtime(m) if m.contains("stage channel closed early"))
}

/// Body of one stage thread: step this group once per timestep,
/// pulling frames from the upstream channel (or the clip itself for
/// stage 0) and pushing output frames downstream (except for the last
/// stage, whose output lives in its Vmem banks).
#[allow(clippy::too_many_arguments)]
fn stage_loop(
    network: &Network,
    span: &GroupSpan,
    vmems: &mut [Mat],
    frames: &[SpikePlane],
    rx: Option<Receiver<SpikePlane>>,
    tx: Option<SyncSender<SpikePlane>>,
    stage: usize,
    epoch: Instant,
) -> Result<StageOutcome> {
    let mut sm = StageMetrics::new(stage, span.layers);
    let mut telemetry = Vec::with_capacity(frames.len());
    for (t, clip_frame) in frames.iter().enumerate() {
        let owned;
        let frame = match &rx {
            None => clip_frame,
            Some(rx) => {
                if t == 0 {
                    // The wait for a clip's first frame is the
                    // pipeline fill front, not upstream starvation:
                    // `fill` (set from the epoch below) already covers
                    // it, so the stall timer stays off and `stall_in`
                    // measures steady state only.
                    owned = rx
                        .recv()
                        .map_err(|_| channel_torn_down(stage, "upstream"))?;
                } else {
                    let wait0 = Instant::now(); // lint: wall-clock
                    owned = rx
                        .recv()
                        .map_err(|_| channel_torn_down(stage, "upstream"))?;
                    sm.stall_in += wait0.elapsed();
                }
                &owned
            }
        };
        if t == 0 {
            sm.fill = epoch.elapsed();
        }
        let busy0 = Instant::now(); // lint: wall-clock
        let (out, tele) = network.step_group(span, frame, vmems)?;
        sm.busy += busy0.elapsed();
        telemetry.push(tele);
        if let Some(tx) = &tx {
            let send0 = Instant::now(); // lint: wall-clock
            tx.send(out)
                .map_err(|_| channel_torn_down(stage, "downstream"))?;
            sm.stall_out += send0.elapsed();
        }
        sm.steps += 1;
    }
    Ok(StageOutcome {
        metrics: sm,
        telemetry,
        finished_at: epoch.elapsed(),
    })
}

/// Run one clip through the staged layer-group pipeline.
///
/// `groups` are contiguous stateful-layer ranges (from
/// [`plan_layer_groups`] / `partition_layer_groups`); each resolves to
/// a [`GroupSpan`] running on its own stage thread over its slice of
/// `state` (disjoint `split_at_mut` partitions — no locking on the
/// step path). Bounded channels of depth `channel_depth` connect
/// adjacent stages; frames flow through them in timestep order, so the
/// result is bit-identical to [`Network::run`] on the same
/// `frames`/`state`: same final Vmem trajectory, same per-step
/// telemetry (returned merged in layer order).
///
/// On a stage error the channels tear down, every other stage unwinds,
/// and the originating stage's error is returned (`state` is left
/// partially stepped — reset it before reuse, as the engines do).
/// Returns the merged telemetry plus one [`StageMetrics`] per stage
/// (occupancy, stall, fill/drain).
pub fn run_pipeline_clip(
    network: &Network,
    frames: &[SpikePlane],
    state: &mut NetworkState,
    groups: &[(usize, usize)],
    channel_depth: usize,
) -> Result<(Vec<StepTelemetry>, Vec<StageMetrics>)> {
    let (c0, h0, w0) = network
        .layers
        .first()
        .ok_or_else(|| Error::config("empty network"))?
        .in_shape;
    for f in frames {
        if f.shape() != (c0, h0, w0) {
            return Err(Error::shape(format!(
                "frame shape {:?} != network input {:?}",
                f.shape(),
                (c0, h0, w0)
            )));
        }
    }
    let spans = network.group_spans(groups)?;
    let needed: usize = spans.iter().map(|s| s.banks()).sum();
    if state.vmems.len() != needed {
        return Err(Error::config(format!(
            "state holds {} Vmem banks, network has {needed} stateful layers",
            state.vmems.len()
        )));
    }
    let depth = channel_depth.max(1);
    let stages = spans.len();

    // Partition the state: each stage owns its group's banks.
    let mut slices: Vec<&mut [Mat]> = Vec::with_capacity(stages);
    let mut rest: &mut [Mat] = &mut state.vmems;
    for span in &spans {
        let (head, tail) = rest.split_at_mut(span.banks());
        slices.push(head);
        rest = tail;
    }

    // Stage threads are fresh each clip: re-bind the caller's trace
    // on each so stage spans attribute to the clip being served.
    let clip_trace = crate::obs::trace::current();
    let epoch = Instant::now(); // lint: wall-clock
    let outcomes: Vec<Result<StageOutcome>> = crate::sync::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(stages);
        let mut prev_rx: Option<Receiver<SpikePlane>> = None;
        for (gi, (span, vmems)) in spans.iter().zip(slices).enumerate() {
            let rx = prev_rx.take();
            let tx = if gi + 1 < stages {
                let (tx, next_rx) = sync_channel(depth);
                prev_rx = Some(next_rx);
                Some(tx)
            } else {
                None
            };
            handles.push(scope.spawn(move || {
                let _tbind = crate::obs::trace::bind(clip_trace);
                let _tspan = crate::obs::trace::span("stage");
                stage_loop(network, span, vmems, frames, rx, tx, gi, epoch)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("pipeline stage panicked"))
            .collect()
    });
    let wall = epoch.elapsed();

    // Prefer a stage's own failure over the secondary channel-teardown
    // errors its neighbours observe.
    let mut teardown: Option<Error> = None;
    let mut stage_outs = Vec::with_capacity(stages);
    for r in outcomes {
        match r {
            Ok(o) => stage_outs.push(o),
            Err(e) if is_channel_teardown(&e) => {
                if teardown.is_none() {
                    teardown = Some(e);
                }
            }
            Err(e) => return Err(e),
        }
    }
    if let Some(e) = teardown {
        return Err(e);
    }

    // Merge the per-group telemetry fragments back into layer order
    // and finish the drain accounting.
    let mut merged: Vec<StepTelemetry> =
        (0..frames.len()).map(|_| StepTelemetry::default()).collect();
    let mut metrics = Vec::with_capacity(stages);
    for o in stage_outs {
        for (t, frag) in o.telemetry.into_iter().enumerate() {
            merged[t].layer_input_spikes.extend(frag.layer_input_spikes);
            merged[t].layer_input_cells.extend(frag.layer_input_cells);
        }
        let mut sm = o.metrics;
        sm.drain = wall.saturating_sub(o.finished_at);
        metrics.push(sm);
    }
    Ok((merged, metrics))
}

/// The pipelined functional serving engine: the third engine on the
/// serving tier beside `ReferenceEngine` (sequential functional) and
/// `ScheduledEngine` (cycle-level multi-core). Each clip runs through
/// [`run_pipeline_clip`] over the layer-group plan fixed at
/// construction; the output is the final accumulator bank,
/// bit-identical to `ReferenceEngine` on the same clip. Vmem state is
/// allocated once and zeroed between clips; [`StageMetrics`]
/// accumulate across clips.
#[derive(Debug, Clone)]
pub struct PipelinedEngine {
    // Private: `state` and `groups` were derived from `network` at
    // construction, so swapping any field independently would desync
    // them.
    network: Network,
    groups: Vec<(usize, usize)>,
    channel_depth: usize,
    state: NetworkState,
    stages: Vec<StageMetrics>,
}

impl PipelinedEngine {
    /// Build an engine around a workload: plan the layer groups,
    /// allocate state once, and zero the per-stage counters.
    pub fn new(network: Network, cfg: PipelineConfig) -> Result<Self> {
        let groups = plan_layer_groups(&network, cfg.stages.max(1));
        if groups.is_empty() {
            return Err(Error::config("network has no stateful layers to pipeline"));
        }
        let spans = network.group_spans(&groups)?;
        let stages = spans
            .iter()
            .enumerate()
            .map(|(i, s)| StageMetrics::new(i, s.layers))
            .collect();
        let state = network.init_state()?;
        Ok(PipelinedEngine {
            network,
            groups,
            channel_depth: cfg.channel_depth.max(1),
            state,
            stages,
        })
    }

    /// The workload this engine serves.
    pub fn network(&self) -> &Network {
        &self.network
    }

    /// The stateful-layer group backing each stage.
    pub fn groups(&self) -> &[(usize, usize)] {
        &self.groups
    }

    /// Per-stage counters accumulated over every clip served so far.
    pub fn stage_metrics(&self) -> &[StageMetrics] {
        &self.stages
    }
}

impl Engine for PipelinedEngine {
    type Output = Vec<i32>;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Vec<i32>> {
        self.state.reset();
        let (_, stage_metrics) = run_pipeline_clip(
            &self.network,
            clip,
            &mut self.state,
            &self.groups,
            self.channel_depth,
        )?;
        for (acc, sm) in self.stages.iter_mut().zip(&stage_metrics) {
            acc.absorb(sm);
        }
        Ok(self
            .state
            .vmems
            .last()
            .map(|m| m.as_slice().to_vec())
            .unwrap_or_default())
    }

    fn stage_metrics(&self) -> Vec<StageMetrics> {
        PipelinedEngine::stage_metrics(self).to_vec()
    }
}

/// The functional engine a server/pool config selects: sequential
/// reference stepping by default, the staged pipeline when
/// `ServerConfig::pipeline` / `PoolConfig::pipeline` is set, the
/// distributed loopback constellation when
/// `ServerConfig::distributed` / `PoolConfig::distributed` is set,
/// the batch-parallel bit-plane engine when `ServerConfig::batch` /
/// `PoolConfig::batch` is set. Every variant emits the final
/// accumulator bank, so outputs are bit-comparable across selections
/// (and across pool workers).
#[derive(Debug)]
pub enum FunctionalEngine {
    /// Sequential whole-network stepping (`Network::step`).
    Reference(ReferenceEngine),
    /// Timestep-pipelined layer-group stepping.
    Pipelined(PipelinedEngine),
    /// Layer groups on self-hosted shard threads behind the wire
    /// protocol (`net`, DESIGN.md §Distributed).
    Distributed(DistributedEngine),
    /// Batch-parallel bit-plane lanes: up to 64 clips swept through
    /// the CIM rows at once ([`super::batch`], DESIGN.md §Perf).
    Batched(BatchedEngine),
}

impl FunctionalEngine {
    /// Build the engine a config selects (all `None` → reference).
    /// The staged, distributed, and batched executors are alternative
    /// datapaths over the same workload, so selecting more than one at
    /// once is a configuration error.
    pub fn from_config(
        network: Network,
        pipeline: Option<PipelineConfig>,
        distributed: Option<DistributedConfig>,
        batch: Option<BatchConfig>,
    ) -> Result<Self> {
        let picked =
            pipeline.is_some() as usize + distributed.is_some() as usize + batch.is_some() as usize;
        if picked > 1 {
            return Err(Error::config(
                "select at most one of the pipelined, distributed, or batched engines",
            ));
        }
        Ok(if let Some(cfg) = pipeline {
            FunctionalEngine::Pipelined(PipelinedEngine::new(network, cfg)?)
        } else if let Some(cfg) = distributed {
            FunctionalEngine::Distributed(DistributedEngine::loopback(network, &cfg)?)
        } else if let Some(cfg) = batch {
            FunctionalEngine::Batched(BatchedEngine::new(network, cfg)?)
        } else {
            FunctionalEngine::Reference(ReferenceEngine::new(network)?)
        })
    }

    /// Accumulated per-stage counters (empty for the reference and
    /// batched variants) — `serve`/`serve_pool` attach these to
    /// `Metrics::stages` automatically via [`Engine::stage_metrics`].
    pub fn stage_metrics(&self) -> &[StageMetrics] {
        match self {
            FunctionalEngine::Reference(_) => &[],
            FunctionalEngine::Pipelined(e) => e.stage_metrics(),
            FunctionalEngine::Distributed(e) => e.stage_metrics(),
            FunctionalEngine::Batched(_) => &[],
        }
    }
}

impl Engine for FunctionalEngine {
    type Output = Vec<i32>;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Vec<i32>> {
        match self {
            FunctionalEngine::Reference(e) => e.infer(clip),
            FunctionalEngine::Pipelined(e) => e.infer(clip),
            FunctionalEngine::Distributed(e) => e.infer(clip),
            FunctionalEngine::Batched(e) => e.infer(clip),
        }
    }

    fn max_batch(&self) -> usize {
        match self {
            FunctionalEngine::Batched(e) => e.max_batch(),
            // 64 on a fully v3 constellation, 1 when a v2 replica
            // pins the negotiated dialect to scalar frames.
            FunctionalEngine::Distributed(e) => e.max_batch(),
            _ => 1,
        }
    }

    fn infer_batch(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<Vec<i32>>> {
        match self {
            FunctionalEngine::Batched(e) => e.infer_batch(clips),
            FunctionalEngine::Distributed(e) => e.infer_batch(clips),
            _ => clips.iter().map(|c| self.infer(c)).collect(),
        }
    }

    fn stage_metrics(&self) -> Vec<StageMetrics> {
        FunctionalEngine::stage_metrics(self).to_vec()
    }

    fn failovers(&self) -> u64 {
        match self {
            FunctionalEngine::Distributed(e) => e.failovers(),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::MultiCoreScheduler;
    use crate::prop::{check, Gen, SplitMix64};
    use crate::quant::Precision;
    use crate::sim::config::SimConfig;
    use crate::snn::layer::{NeuronConfig, ResetMode};
    use crate::snn::network::NetworkBuilder;

    fn rand_mat(g: &mut Gen, rows: usize, cols: usize) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, g.i32_in(-7..=7));
            }
        }
        m
    }

    /// A random spiking network: 1–3 hidden conv layers (random
    /// channels, thresholds, leaks, reset modes), an optional pool,
    /// and an accumulate FC readout.
    fn random_network(g: &mut Gen) -> Network {
        let in_ch = 1 + g.index(2);
        let h = 4 + 2 * g.index(3);
        let w = 4 + 2 * g.index(3);
        let hidden = 1 + g.index(3);
        let pool_after = g.index(hidden + 1); // == hidden means "none"
        let mut b = NetworkBuilder::new("prop-pipe", Precision::W4V7, 3, (in_ch, h, w));
        for i in 0..hidden {
            let (c, _, _) = b.shape();
            let out_ch = 2 + g.index(5);
            let neuron = NeuronConfig {
                theta: 1 + g.i32_in(0..=6),
                leak: g.i32_in(0..=2),
                leaky: g.chance(0.5),
                reset: if g.chance(0.5) {
                    ResetMode::Soft
                } else {
                    ResetMode::Hard
                },
            };
            let wm = rand_mat(g, c * 9, out_ch);
            b = b.conv3x3(out_ch, wm, neuron, false).unwrap();
            if i == pool_after {
                b = b.pool(2, 2);
            }
        }
        let (c, hh, ww) = b.shape();
        let out = 2 + g.index(3);
        let wm = rand_mat(g, c * hh * ww, out);
        b.fc(out, wm, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    fn random_frames(g: &mut Gen, net: &Network, t: usize) -> Vec<SpikePlane> {
        let (c, h, w) = net.layers[0].in_shape;
        let density = 0.1 + g.f64() * 0.4;
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(c, h, w);
                for i in 0..p.len() {
                    if g.chance(density) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    fn demo_net() -> Network {
        crate::snn::network::demo_serving_network(6).unwrap()
    }

    fn demo_clip(seed: u64, t: usize) -> Vec<SpikePlane> {
        let mut rng = SplitMix64::new(seed);
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(2, 16, 16);
                for i in 0..p.len() {
                    if rng.chance(0.2) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    #[test]
    fn pipeline_matches_sequential_reference() {
        let net = demo_net();
        let frames = demo_clip(9, 6);

        let mut ref_state = net.init_state().unwrap();
        let ref_tel = net.run(&frames, &mut ref_state).unwrap();

        let groups = plan_layer_groups(&net, 2);
        assert_eq!(groups.len(), 2);
        let mut pipe_state = net.init_state().unwrap();
        let (tel, stages) = run_pipeline_clip(&net, &frames, &mut pipe_state, &groups, 2).unwrap();

        for (a, b) in ref_state.vmems.iter().zip(&pipe_state.vmems) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        assert_eq!(tel, ref_tel);
        assert_eq!(stages.len(), 2);
        for (gi, sm) in stages.iter().enumerate() {
            assert_eq!(sm.stage, gi);
            assert_eq!(sm.steps, 6);
            assert!(sm.occupancy() > 0.0 && sm.occupancy() <= 1.0);
        }
        // the fill front reaches later stages later
        assert!(stages[1].fill >= stages[0].fill);
    }

    #[test]
    fn single_group_pipeline_is_sequential() {
        let net = demo_net();
        let frames = demo_clip(11, 4);
        let mut ref_state = net.init_state().unwrap();
        net.run(&frames, &mut ref_state).unwrap();
        let mut state = net.init_state().unwrap();
        let (_, stages) = run_pipeline_clip(&net, &frames, &mut state, &[(0, 2)], 1).unwrap();
        assert_eq!(stages.len(), 1);
        assert_eq!(stages[0].stall_out, Duration::ZERO);
        for (a, b) in ref_state.vmems.iter().zip(&state.vmems) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
    }

    /// Regression: the wait for a clip's first frame is the fill
    /// front and must land in `fill`, not `stall_in` — it used to hit
    /// both, so a deep pipeline's downstream stages read as starved
    /// (low `occupancy`) during a perfectly normal fill.
    #[test]
    fn fill_front_is_not_accounted_as_starvation() {
        let net = demo_net();
        let frames = demo_clip(13, 4);
        let mut state = net.init_state().unwrap();
        let spans = net.group_spans(&[(0, 2)]).unwrap();

        // Producer holds the first frame back, then releases the
        // whole clip at once: every wait after the first is ~zero.
        let delay = Duration::from_millis(40);
        let (tx, rx) = sync_channel::<SpikePlane>(frames.len());
        let producer = crate::sync::thread::spawn({
            let frames = frames.clone();
            move || {
                std::thread::sleep(delay);
                for f in frames {
                    tx.send(f).unwrap();
                }
            }
        });
        let epoch = Instant::now();
        let out = stage_loop(
            &net,
            &spans[0],
            &mut state.vmems,
            &frames,
            Some(rx),
            None,
            1,
            epoch,
        )
        .unwrap();
        producer.join().unwrap();

        let sm = out.metrics;
        assert_eq!(sm.steps, frames.len() as u64);
        assert!(sm.fill >= delay, "fill front missing: {:?}", sm.fill);
        assert!(
            sm.stall_in < delay / 2,
            "fill front leaked into stall_in: {:?}",
            sm.stall_in
        );
    }

    #[test]
    fn empty_clip_is_a_noop() {
        let net = demo_net();
        let mut state = net.init_state().unwrap();
        let groups = plan_layer_groups(&net, 2);
        let (tel, stages) = run_pipeline_clip(&net, &[], &mut state, &groups, 1).unwrap();
        assert!(tel.is_empty());
        assert!(stages.iter().all(|s| s.steps == 0));
        assert!(state.vmems.iter().all(|v| v.as_slice().iter().all(|&x| x == 0)));
    }

    #[test]
    fn bad_frame_shape_rejected_before_spawning() {
        let net = demo_net();
        let mut state = net.init_state().unwrap();
        let groups = plan_layer_groups(&net, 2);
        let wrong = vec![SpikePlane::zeros(2, 8, 8)];
        assert!(run_pipeline_clip(&net, &wrong, &mut state, &groups, 1).is_err());
    }

    /// A stage failing mid-clip tears the channels down; the
    /// originating stage's error (not a neighbour's secondary
    /// channel error) comes back.
    #[test]
    fn stage_error_propagates_as_the_root_cause() {
        // Hand-build a network whose second stateful layer is broken
        // (no weights) — the builder can't make one, the struct can.
        let good = crate::snn::layer::Layer::conv(
            (1, 4, 4),
            2,
            3,
            3,
            1,
            1,
            Mat::zeros(9, 2),
            NeuronConfig::default(),
            false,
        )
        .unwrap();
        let mut bad = crate::snn::layer::Layer::fc(
            (2, 4, 4),
            3,
            Mat::zeros(32, 3),
            NeuronConfig::default(),
            true,
        )
        .unwrap();
        bad.weights = None;
        let net = Network {
            name: "broken".into(),
            layers: vec![good, bad],
            precision: Precision::W4V7,
            timesteps: 4,
        };
        let mut state = net.init_state().unwrap();
        let frames: Vec<SpikePlane> = (0..4).map(|_| SpikePlane::zeros(1, 4, 4)).collect();
        let err = run_pipeline_clip(&net, &frames, &mut state, &[(0, 1), (1, 2)], 1).unwrap_err();
        assert!(
            matches!(err, Error::Config(ref m) if m.contains("weights")),
            "want the broken layer's error, got: {err}"
        );
    }

    #[test]
    fn engine_resets_between_clips_and_accumulates_stage_metrics() {
        let net = demo_net();
        let clip = demo_clip(21, 6);
        let mut ref_engine = ReferenceEngine::new(net.clone()).unwrap();
        let want = ref_engine.infer(&clip).unwrap();

        let mut e = PipelinedEngine::new(net, PipelineConfig::with_stages(2)).unwrap();
        let a = e.infer(&clip).unwrap();
        let b = e.infer(&clip).unwrap();
        assert_eq!(a, want, "pipelined output != reference output");
        assert_eq!(a, b, "state must reset between clips");
        assert_eq!(e.groups().len(), 2);
        // counters accumulated over both clips
        assert!(e.stage_metrics().iter().all(|s| s.steps == 12));
    }

    #[test]
    fn from_config_selects_the_engine() {
        let net = demo_net();
        let clip = demo_clip(33, 4);
        let mut r = FunctionalEngine::from_config(net.clone(), None, None, None).unwrap();
        assert!(matches!(&r, FunctionalEngine::Reference(_)));
        assert!(r.stage_metrics().is_empty());
        assert_eq!(r.max_batch(), 1);
        let want = r.infer(&clip).unwrap();

        let mut p = FunctionalEngine::from_config(
            net.clone(),
            Some(PipelineConfig::with_stages(2)),
            None,
            None,
        )
        .unwrap();
        assert!(matches!(&p, FunctionalEngine::Pipelined(_)));
        assert_eq!(p.infer(&clip).unwrap(), want);
        assert_eq!(p.stage_metrics().len(), 2);

        let mut d = FunctionalEngine::from_config(
            net.clone(),
            None,
            Some(DistributedConfig::with_shards(2)),
            None,
        )
        .unwrap();
        assert!(matches!(&d, FunctionalEngine::Distributed(_)));
        assert_eq!(d.infer(&clip).unwrap(), want);
        assert_eq!(d.stage_metrics().len(), 2);
        // loopback shards all speak v3, so lane batching is on
        assert_eq!(d.max_batch(), 64);

        let mut b = FunctionalEngine::from_config(
            net.clone(),
            None,
            None,
            Some(BatchConfig::default()),
        )
        .unwrap();
        assert!(matches!(&b, FunctionalEngine::Batched(_)));
        assert_eq!(b.infer(&clip).unwrap(), want);
        assert_eq!(b.max_batch(), 64);
        assert!(b.stage_metrics().is_empty());

        // the alternative executors are not composable
        assert!(FunctionalEngine::from_config(
            net.clone(),
            Some(PipelineConfig::default()),
            Some(DistributedConfig::default()),
            None,
        )
        .is_err());
        assert!(FunctionalEngine::from_config(
            net,
            Some(PipelineConfig::default()),
            None,
            Some(BatchConfig::default()),
        )
        .is_err());
    }

    /// Satellite: pipelined execution is bit-identical to
    /// `Network::run` *and* to the scheduler's `run_network_clip`
    /// across random networks, group counts, channel depths, and
    /// timestep counts.
    #[test]
    fn prop_pipeline_bit_identical_to_reference() {
        check("pipeline_bit_identical", 12, |g| {
            let net = random_network(g);
            let t = 1 + g.index(4);
            let frames = random_frames(g, &net, t);
            let stateful = net.stateful_layers().count();
            let stages = 1 + g.index(stateful + 2); // may exceed the layer count
            let depth = 1 + g.index(3);

            // sequential reference
            let mut ref_state = net.init_state().unwrap();
            let ref_tel = net.run(&frames, &mut ref_state).unwrap();

            // staged pipeline
            let groups = plan_layer_groups(&net, stages);
            let mut pipe_state = net.init_state().unwrap();
            let (tel, _) =
                run_pipeline_clip(&net, &frames, &mut pipe_state, &groups, depth).unwrap();

            // cycle-level scheduler path (shares the per-group core)
            let sched = MultiCoreScheduler::new(1 + g.index(3), SimConfig::default());
            let mut sim_state = net.init_state().unwrap();
            sched.run_network_clip(&net, &frames, &mut sim_state).unwrap();

            tel == ref_tel
                && ref_state
                    .vmems
                    .iter()
                    .zip(&pipe_state.vmems)
                    .all(|(a, b)| a.as_slice() == b.as_slice())
                && ref_state
                    .vmems
                    .iter()
                    .zip(&sim_state.vmems)
                    .all(|(a, b)| a.as_slice() == b.as_slice())
        });
    }
}
