//! Multi-core scheduler (paper §II-E: "easily scalable to a multi-core
//! architecture where each core can process independent output neurons
//! in parallel, increasing throughput without additional data
//! movement").
//!
//! Output channels are partitioned across cores; each core runs the
//! same input stream against its channel slice. Host-side execution
//! uses real threads (one per simulated core); simulated time is the
//! max over cores, energy the sum (plus idle leakage on the laggards).
//!
//! For the serving tier the same partitioning generalizes one level
//! up: [`MultiCoreScheduler::partition_layer_groups`] shards a
//! multi-layer network's stateful layers into contiguous,
//! cost-balanced groups — the layer-stationary placement a pool
//! worker keeps resident — and [`ScheduledEngine`] adapts whole-clip
//! multi-core execution to the [`Engine`] trait so the pool can wrap
//! simulated cores directly (DESIGN.md §Serve).

use crate::error::{Error, Result};
use crate::sim::config::SimConfig;
use crate::sim::core::SpidrCore;
use crate::sim::stats::RunStats;
use crate::snn::layer::{Layer, LayerKind};
use crate::snn::network::{pool_step, GroupSpan, Network, NetworkState};
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

use super::server::Engine;

/// Multi-core scheduler over `num_cores` SpiDR cores.
#[derive(Debug, Clone)]
pub struct MultiCoreScheduler {
    /// Cores available.
    pub num_cores: usize,
    /// Per-core configuration.
    pub cfg: SimConfig,
}

/// Multi-core run result.
#[derive(Debug, Clone)]
pub struct MultiCoreStats {
    /// Simulated makespan (max over cores).
    pub cycles: u64,
    /// Total energy (sum of dynamic over cores; leakage over all
    /// cores for the full makespan).
    pub run: RunStats,
    /// Per-core cycle counts (load-balance diagnostics).
    pub per_core_cycles: Vec<u64>,
    /// Simulated cycles per layer group (one entry per group on the
    /// [`MultiCoreScheduler::run_network_clip`] path; empty for
    /// single-layer runs) — the stage costs of the fill/drain latency
    /// model (DESIGN.md §Pipeline).
    pub per_group_cycles: Vec<u64>,
}

impl MultiCoreStats {
    /// Empty stats, ready for accumulation.
    fn empty() -> Self {
        MultiCoreStats {
            cycles: 0,
            run: RunStats::default(),
            per_core_cycles: Vec::new(),
            per_group_cycles: Vec::new(),
        }
    }

    /// Fold one layer/group result into this accumulator: cycles add
    /// (layers/groups run back to back on the sequential path), core
    /// cycle counters add index-wise, energies and op counts sum.
    /// `per_group_cycles` is *not* folded — the clip executor records
    /// one entry per group itself.
    fn accumulate(&mut self, part: &MultiCoreStats) {
        self.cycles += part.cycles;
        self.run.add(&part.run);
        for (i, c) in part.per_core_cycles.iter().enumerate() {
            if i >= self.per_core_cycles.len() {
                self.per_core_cycles.push(0);
            }
            self.per_core_cycles[i] += c;
        }
    }

    /// Modeled single-clip makespan if the recorded layer groups ran
    /// as a timestep-staged pipeline instead of back to back:
    /// `T_clip ≈ (G−1)·t_stage + T·t_stage`, with `t_stage` the
    /// slowest group's per-timestep cost (DESIGN.md §Pipeline). Falls
    /// back to the sequential `cycles` when no group breakdown was
    /// recorded or `timesteps` is zero.
    pub fn pipelined_cycle_estimate(&self, timesteps: u64) -> u64 {
        let g = self.per_group_cycles.len() as u64;
        if g == 0 || timesteps == 0 {
            return self.cycles;
        }
        let t_stage = self
            .per_group_cycles
            .iter()
            .map(|c| c.div_ceil(timesteps))
            .max()
            .unwrap_or(0);
        (g - 1 + timesteps) * t_stage
    }
}

impl MultiCoreScheduler {
    /// New scheduler.
    pub fn new(num_cores: usize, cfg: SimConfig) -> Self {
        MultiCoreScheduler { num_cores, cfg }
    }

    /// Partition output channels `0..k` across cores (contiguous,
    /// balanced — [`balanced_partition`] over unit costs).
    pub fn partition_channels(&self, k: usize) -> Vec<(usize, usize)> {
        balanced_partition(&vec![1u64; k], self.num_cores)
    }

    /// Plan how a network's **stateful layers** shard into contiguous
    /// groups, one per core/pool-worker/pipeline-stage, balancing the
    /// per-layer dense-synaptic-op cost greedily — the
    /// layer-stationary analogue of [`Self::partition_channels`].
    /// Networks with fewer stateful layers than cores get one group
    /// per layer (never an empty group); a network with no stateful
    /// layers gets no groups. Ranges index `stateful_layers()` order.
    /// This plan is the stage topology of the timestep pipeline
    /// (`coordinator::pipeline`, DESIGN.md §Pipeline) and becomes the
    /// actual placement when layer groups move to separate
    /// processes/hosts (ROADMAP "Cross-process sharding").
    pub fn partition_layer_groups(&self, network: &Network) -> Vec<(usize, usize)> {
        plan_layer_groups(network, self.num_cores)
    }

    /// Run one layer's timesteps across cores (channel-parallel).
    ///
    /// `state` is the full `(M, K)` Vmem bank; each core updates its
    /// channel slice. Output planes are merged across cores.
    pub fn run_layer(
        &self,
        layer: &Layer,
        inputs: &[SpikePlane],
        state: &mut Mat,
    ) -> Result<(Vec<SpikePlane>, MultiCoreStats)> {
        let k = layer.out_shape.0;
        let parts = self.partition_channels(k);
        let weights = layer
            .weights
            .as_ref()
            .ok_or_else(|| Error::mapping("pool layer on scheduler"))?;
        let (m_total, _) = layer.vmem_shape()?;

        // Build per-core sub-layers (channel slices of the weights,
        // via row-slice block copies — §Perf).
        let mut jobs = Vec::new();
        for &(ks, ke) in &parts {
            let mut sub = layer.clone();
            sub.weights = Some(weights.submatrix(0, weights.rows, ks, ke));
            sub.out_shape = (ke - ks, layer.out_shape.1, layer.out_shape.2);
            // initial sub-state from the big bank
            let sub_state = state.submatrix(0, m_total, ks, ke);
            jobs.push((sub, sub_state, ks, ke));
        }

        // Host-parallel execution, one thread per core.
        let cfg = self.cfg;
        let results: Vec<(Vec<SpikePlane>, crate::sim::core::LayerStats, Mat, usize, usize)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = jobs
                    .into_iter()
                    .map(|(sub, mut sub_state, ks, ke)| {
                        let inputs = &inputs;
                        scope.spawn(move || {
                            let core = SpidrCore::new(cfg);
                            let (out, stats) =
                                core.run_layer(&sub, inputs, &mut sub_state)?;
                            Ok::<_, crate::error::Error>((out, stats, sub_state, ks, ke))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("core thread panicked"))
                    .collect::<Result<Vec<_>>>()
            })?;

        // Merge: outputs, state slices, stats.
        let (ko, ho, wo) = layer.out_shape;
        let mut outputs: Vec<SpikePlane> = (0..inputs.len())
            .map(|_| SpikePlane::zeros(ko, ho, wo))
            .collect();
        let mut run = RunStats::default();
        let mut per_core_cycles = Vec::new();
        let mut makespan = 0u64;
        for (out, stats, sub_state, ks, ke) in results {
            for (t, plane) in out.iter().enumerate() {
                for (c, kk) in (ks..ke).enumerate() {
                    for y in 0..ho {
                        for x in 0..wo {
                            if plane.get(c, y, x) != 0 {
                                outputs[t].set(kk, y, x, 1);
                            }
                        }
                    }
                }
            }
            for m in 0..m_total {
                for (c, kk) in (ks..ke).enumerate() {
                    state.set(m, kk, sub_state.get(m, c));
                }
            }
            per_core_cycles.push(stats.run.cycles);
            makespan = makespan.max(stats.run.cycles);
            // dense_synops / spikes / cells are per-layer quantities;
            // merge energies and op counts, then fix telemetry below.
            run.energy.add(&stats.run.energy);
            run.macro_ops += stats.run.macro_ops;
            run.synops += stats.run.synops;
            run.parity_switches += stats.run.parity_switches;
        }
        run.cycles = makespan;
        run.dense_synops = layer.dense_synops() * inputs.len() as u64;
        for inp in inputs {
            run.spikes += inp.count_spikes();
            run.cells += inp.len() as u64;
        }
        // idle cores leak for the full makespan
        let leak_scale = (cfg.corner.voltage / 0.9).powi(2);
        run.energy.leakage = self.num_cores as f64
            * cfg.energy.p_leak_mw
            * leak_scale
            * cfg.corner.period_ns()
            * makespan as f64;

        Ok((
            outputs,
            MultiCoreStats {
                cycles: makespan,
                run,
                per_core_cycles,
                per_group_cycles: Vec::new(),
            },
        ))
    }

    /// Run one layer-group span over a clip — the per-group building
    /// block shared by [`Self::run_network_clip`] (groups back to
    /// back) and a cycle-level pipeline stage (one group per stage
    /// thread; `coordinator::pipeline`, DESIGN.md §Pipeline). Pool
    /// layers run in the loader, as on silicon; every stateful
    /// layer's output channels shard across the simulated cores.
    /// `vmems` must hold exactly the span's Vmem banks in
    /// stateful-layer order (the span's slice of
    /// [`NetworkState::vmems`]).
    pub fn run_group(
        &self,
        network: &Network,
        span: &GroupSpan,
        mut planes: Vec<SpikePlane>,
        vmems: &mut [Mat],
    ) -> Result<(Vec<SpikePlane>, MultiCoreStats)> {
        if vmems.len() != span.banks() {
            return Err(Error::config(format!(
                "group state holds {} Vmem banks, span {:?} needs {}",
                vmems.len(),
                span.stateful,
                span.banks()
            )));
        }
        let mut total = MultiCoreStats::empty();
        let mut si = 0;
        for layer in &network.layers[span.layers.0..span.layers.1] {
            match layer.kind {
                LayerKind::Pool => {
                    planes = planes.iter().map(|p| pool_step(layer, p)).collect();
                }
                LayerKind::Conv | LayerKind::Fc => {
                    let (out, stats) = self.run_layer(layer, &planes, &mut vmems[si])?;
                    total.accumulate(&stats);
                    planes = out;
                    si += 1;
                }
            }
        }
        Ok((planes, total))
    }

    /// Run a whole multi-layer clip, sharding **every stateful layer's
    /// output channels** across the simulated cores. Execution
    /// delegates to [`Self::run_group`] over the layer-group plan of
    /// [`Self::partition_layer_groups`] — the same per-group stepping
    /// core the timestep pipeline drives — with the groups running
    /// back to back: layer `l` at timestep `t` consumes layer `l−1`'s
    /// spikes, simulated cycles add across layers/groups, and each
    /// layer's makespan is the max over its channel shards.
    /// [`MultiCoreStats::per_group_cycles`] records the per-group
    /// split (one entry per group). `state` must come from
    /// [`Network::init_state`] (reset it between independent clips).
    pub fn run_network_clip(
        &self,
        network: &Network,
        frames: &[SpikePlane],
        state: &mut NetworkState,
    ) -> Result<(Vec<SpikePlane>, MultiCoreStats)> {
        let (c0, h0, w0) = network
            .layers
            .first()
            .ok_or_else(|| Error::config("empty network"))?
            .in_shape;
        for f in frames {
            if f.shape() != (c0, h0, w0) {
                return Err(Error::shape(format!(
                    "frame shape {:?} != network input {:?}",
                    f.shape(),
                    (c0, h0, w0)
                )));
            }
        }
        let spans = network.group_spans(&self.partition_layer_groups(network))?;
        let mut planes: Vec<SpikePlane> = frames.to_vec();
        let mut total = MultiCoreStats::empty();
        let mut si = 0;
        for span in &spans {
            let banks = span.banks();
            let (out, stats) =
                self.run_group(network, span, planes, &mut state.vmems[si..si + banks])?;
            total.accumulate(&stats);
            total.per_group_cycles.push(stats.cycles);
            planes = out;
            si += banks;
        }
        Ok((planes, total))
    }
}

/// Plan how a network's stateful layers shard into at most `groups`
/// contiguous, dense-synaptic-op-balanced groups (see
/// [`MultiCoreScheduler::partition_layer_groups`]). A free function so
/// the pipeline can plan stages without constructing a scheduler.
pub fn plan_layer_groups(network: &Network, groups: usize) -> Vec<(usize, usize)> {
    let costs: Vec<u64> = network.stateful_layers().map(|l| l.dense_synops()).collect();
    balanced_partition(&costs, groups)
}

/// Per-group dense-synaptic-op cost of a stateful-layer partition (as
/// produced by [`plan_layer_groups`]): the compute-demand vector the
/// deployment planner (`net::plan`, DESIGN.md §Planner) scales by its
/// calibrated per-synop cost to estimate each hop's per-timestep
/// service time.
pub fn plan_layer_group_costs(network: &Network, groups: &[(usize, usize)]) -> Vec<u64> {
    let costs: Vec<u64> = network.stateful_layers().map(|l| l.dense_synops()).collect();
    groups
        .iter()
        .map(|&(a, b)| costs[a.min(costs.len())..b.min(costs.len())].iter().sum())
        .collect()
}

/// Contiguous, cost-balanced partition of `costs` into at most `n`
/// **non-empty** groups — the shared core of
/// [`MultiCoreScheduler::partition_channels`] (unit costs) and
/// [`plan_layer_groups`] (dense-synop costs).
///
/// Greedy fair-share closing: the open group closes once it reaches
/// `ceil(remaining_cost / groups_left)`, but never so early that a
/// later group would end up empty, and never so late that the
/// remaining items cannot give every later group at least one. Edge
/// cases: fewer items than `n` yields one group per item; a single
/// item yields one group; zero-cost items close immediately (their
/// fair share is zero) but still land in non-empty groups; an empty
/// cost list yields no groups.
pub fn balanced_partition(costs: &[u64], n: usize) -> Vec<(usize, usize)> {
    let s = costs.len();
    if s == 0 {
        return Vec::new();
    }
    let n = n.min(s).max(1);
    let total: u64 = costs.iter().sum();
    let mut groups = Vec::with_capacity(n);
    let mut lo = 0usize;
    let mut acc = 0u64;
    let mut served = 0u64;
    for (i, &c) in costs.iter().enumerate() {
        acc += c;
        let groups_left = n - groups.len(); // incl. the open group
        if groups_left == 1 {
            continue; // the last group swallows the tail
        }
        let items_left = s - i - 1;
        let fair = (total - served).div_ceil(groups_left as u64);
        if items_left >= groups_left - 1 && (acc >= fair || items_left == groups_left - 1) {
            groups.push((lo, i + 1));
            lo = i + 1;
            served += acc;
            acc = 0;
        }
    }
    groups.push((lo, s));
    groups
}

/// [`Engine`] adapter over the multi-core scheduler: each clip is an
/// independent inference of a multi-layer network, with every layer's
/// channels sharded across the scheduler's simulated cores. This is
/// the engine a pool worker wraps to put the cycle-level simulator on
/// the sharded request path (DESIGN.md §Serve); its Vmem state is
/// allocated once and zeroed between clips.
#[derive(Debug, Clone)]
pub struct ScheduledEngine {
    // Private: `state` was sized for `network` at construction, so
    // swapping either field independently would desync them.
    network: Network,
    scheduler: MultiCoreScheduler,
    state: NetworkState,
}

impl ScheduledEngine {
    /// Build an engine around a workload (allocates state once).
    pub fn new(network: Network, scheduler: MultiCoreScheduler) -> Result<Self> {
        let state = network.init_state()?;
        Ok(ScheduledEngine {
            network,
            scheduler,
            state,
        })
    }

    /// The workload this engine serves.
    pub fn network(&self) -> &Network {
        &self.network
    }

    /// The scheduler sharding each layer across simulated cores.
    pub fn scheduler(&self) -> &MultiCoreScheduler {
        &self.scheduler
    }
}

impl Engine for ScheduledEngine {
    type Output = MultiCoreStats;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<MultiCoreStats> {
        self.state.reset();
        let (_, stats) =
            self.scheduler
                .run_network_clip(&self.network, clip, &mut self.state)?;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::SplitMix64;
    use crate::snn::layer::NeuronConfig;

    fn layer(out_ch: usize) -> Layer {
        let mut w = Mat::zeros(18, out_ch);
        for f in 0..18 {
            for k in 0..out_ch {
                w.set(f, k, ((f * 3 + k) % 7) as i32 - 3);
            }
        }
        let neuron = NeuronConfig {
            theta: 4,
            ..Default::default()
        };
        Layer::conv((2, 6, 6), out_ch, 3, 3, 1, 1, w, neuron, false).unwrap()
    }

    fn frames(t: usize) -> Vec<SpikePlane> {
        let mut rng = SplitMix64::new(3);
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(2, 6, 6);
                for i in 0..p.len() {
                    if rng.chance(0.25) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    #[test]
    fn partition_is_balanced_and_complete() {
        let s = MultiCoreScheduler::new(4, SimConfig::default());
        let parts = s.partition_channels(10);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|(a, b)| b - a).sum();
        assert_eq!(total, 10);
        // unit costs split as evenly as possible: sizes differ by ≤ 1
        let sizes: Vec<usize> = parts.iter().map(|(a, b)| b - a).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    /// Every partition the helper returns is contiguous, covering,
    /// and free of empty groups.
    fn assert_valid_partition(parts: &[(usize, usize)], items: usize) {
        assert_eq!(parts.first().map(|p| p.0), Some(0));
        assert_eq!(parts.last().map(|p| p.1), Some(items));
        for w in parts.windows(2) {
            assert_eq!(w[0].1, w[1].0, "partition must be contiguous");
        }
        assert!(parts.iter().all(|(a, b)| a < b), "no empty group");
    }

    #[test]
    fn balanced_partition_edge_cases() {
        // more groups than items: one group per item
        assert_eq!(balanced_partition(&[5, 7], 8), vec![(0, 1), (1, 2)]);
        // single item
        assert_eq!(balanced_partition(&[9], 4), vec![(0, 1)]);
        // empty cost list: no groups
        assert!(balanced_partition(&[], 3).is_empty());
        // zero-cost items still land in non-empty covering groups
        let z = balanced_partition(&[0, 0, 0, 0], 2);
        assert_eq!(z.len(), 2);
        assert_valid_partition(&z, 4);
        // a dominant item takes a group of its own
        assert_eq!(balanced_partition(&[100, 1, 1, 1], 2), vec![(0, 1), (1, 4)]);
        // n = 0 is clamped to one group
        assert_eq!(balanced_partition(&[3, 3], 0), vec![(0, 2)]);
        // mixed zero/non-zero costs stay valid at every group count
        for n in 1..=6 {
            let p = balanced_partition(&[0, 4, 0, 0, 9, 1], n);
            assert_valid_partition(&p, 6);
            assert!(p.len() <= n.max(1));
        }
    }

    #[test]
    fn multicore_matches_single_core_function() {
        let l = layer(8);
        let fs = frames(2);

        let single = MultiCoreScheduler::new(1, SimConfig::default());
        let mut state1 = Mat::zeros(36, 8);
        let (out1, st1) = single.run_layer(&l, &fs, &mut state1).unwrap();

        let quad = MultiCoreScheduler::new(4, SimConfig::default());
        let mut state4 = Mat::zeros(36, 8);
        let (out4, st4) = quad.run_layer(&l, &fs, &mut state4).unwrap();

        assert_eq!(state1.as_slice(), state4.as_slice());
        for (a, b) in out1.iter().zip(&out4) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        // more cores -> shorter makespan (or equal for degenerate work)
        assert!(st4.cycles <= st1.cycles);
        assert_eq!(st4.per_core_cycles.len(), 4);
    }

    fn tiny_network() -> Network {
        use crate::quant::Precision;
        use crate::snn::network::NetworkBuilder;
        let mut w1 = Mat::zeros(9, 4);
        for f in 0..9 {
            for k in 0..4 {
                w1.set(f, k, ((f + 2 * k) % 5) as i32 - 2);
            }
        }
        let w2 = Mat::zeros(4 * 4 * 4, 2);
        NetworkBuilder::new("sched-tiny", Precision::W4V7, 2, (1, 8, 8))
            .conv3x3(4, w1, NeuronConfig { theta: 3, ..Default::default() }, false)
            .unwrap()
            .pool(2, 2)
            .fc(2, w2, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    #[test]
    fn layer_groups_cover_all_stateful_layers_contiguously() {
        let net = tiny_network(); // 2 stateful layers (conv, fc)
        for cores in [1usize, 2, 3, 8] {
            let s = MultiCoreScheduler::new(cores, SimConfig::default());
            let groups = s.partition_layer_groups(&net);
            assert_eq!(groups.len(), cores.min(2));
            assert_valid_partition(&groups, 2);
        }
    }

    /// Satellite: a network with fewer stateful layers than cores gets
    /// one non-empty group per layer — callers can always feed the
    /// plan straight into `Network::group_spans` regardless of the
    /// core/worker/stage count.
    #[test]
    fn layer_groups_with_fewer_layers_than_cores() {
        let net = tiny_network(); // 2 stateful layers
        for cores in [3usize, 4, 17] {
            let s = MultiCoreScheduler::new(cores, SimConfig::default());
            let groups = s.partition_layer_groups(&net);
            assert_eq!(groups, vec![(0, 1), (1, 2)]);
            // and the plan resolves to spans without caller-side fixups
            let spans = net.group_spans(&groups).unwrap();
            assert_eq!(spans.len(), 2);
        }
        // free-function form, single stateful layer
        use crate::quant::Precision;
        use crate::snn::network::NetworkBuilder;
        let one = NetworkBuilder::new("one", Precision::W4V7, 1, (1, 4, 4))
            .conv3x3(2, Mat::zeros(9, 2), NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(plan_layer_groups(&one, 6), vec![(0, 1)]);
    }

    #[test]
    fn layer_groups_balance_cost() {
        // 6 equal-cost stateful layers over 3 workers -> 2 each.
        use crate::quant::Precision;
        use crate::snn::network::NetworkBuilder;
        let mut b = NetworkBuilder::new("six", Precision::W4V7, 1, (2, 6, 6));
        for i in 0..6 {
            // the builder requires an accumulate output layer
            b = b
                .conv3x3(2, Mat::zeros(18, 2), NeuronConfig::default(), i == 5)
                .unwrap();
        }
        let net = b.build().unwrap();
        let s = MultiCoreScheduler::new(3, SimConfig::default());
        let groups = s.partition_layer_groups(&net);
        assert_eq!(groups, vec![(0, 2), (2, 4), (4, 6)]);
    }

    /// Group-at-a-time execution composes to the same trajectory as
    /// the whole-clip executor (they share `run_group`).
    #[test]
    fn run_group_composes_to_network_clip() {
        let net = tiny_network();
        let fs = {
            let mut rng = SplitMix64::new(5);
            (0..2)
                .map(|_| {
                    let mut p = SpikePlane::zeros(1, 8, 8);
                    for i in 0..p.len() {
                        if rng.chance(0.3) {
                            p.as_mut_slice()[i] = 1;
                        }
                    }
                    p
                })
                .collect::<Vec<_>>()
        };
        let s = MultiCoreScheduler::new(2, SimConfig::default());
        let mut whole = net.init_state().unwrap();
        let (out_whole, _) = s.run_network_clip(&net, &fs, &mut whole).unwrap();

        let spans = net.group_spans(&[(0, 1), (1, 2)]).unwrap();
        let mut grouped = net.init_state().unwrap();
        let (g0, g1) = grouped.vmems.split_at_mut(1);
        let (mid, _) = s.run_group(&net, &spans[0], fs.clone(), g0).unwrap();
        let (out_grouped, _) = s.run_group(&net, &spans[1], mid, g1).unwrap();

        for (a, b) in whole.vmems.iter().zip(&grouped.vmems) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        for (a, b) in out_whole.iter().zip(&out_grouped) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        // bank-count mismatch is rejected
        let mut bad = net.init_state().unwrap();
        assert!(s.run_group(&net, &spans[0], fs, &mut bad.vmems).is_err());
    }

    #[test]
    fn network_clip_matches_reference_executor() {
        let net = tiny_network();
        let fs: Vec<SpikePlane> = {
            let mut rng = SplitMix64::new(17);
            (0..2)
                .map(|_| {
                    let mut p = SpikePlane::zeros(1, 8, 8);
                    for i in 0..p.len() {
                        if rng.chance(0.3) {
                            p.as_mut_slice()[i] = 1;
                        }
                    }
                    p
                })
                .collect()
        };

        // reference trajectory
        let mut ref_state = net.init_state().unwrap();
        for f in &fs {
            net.step(f, &mut ref_state).unwrap();
        }

        // channel-sharded multi-core trajectory
        let s = MultiCoreScheduler::new(3, SimConfig::default());
        let mut state = net.init_state().unwrap();
        let (_, stats) = s.run_network_clip(&net, &fs, &mut state).unwrap();

        for (a, b) in ref_state.vmems.iter().zip(&state.vmems) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        assert!(stats.cycles > 0);
        assert!(!stats.per_core_cycles.is_empty());
        // per-group split: one entry per layer group, summing to the
        // sequential makespan, and the pipelined estimate beats it
        // once there is more than one group.
        let groups = s.partition_layer_groups(&net);
        assert_eq!(stats.per_group_cycles.len(), groups.len());
        assert_eq!(stats.per_group_cycles.iter().sum::<u64>(), stats.cycles);
        // fill/drain model: (G-1+T)·t_stage with t_stage the slowest
        // group's per-timestep cost
        let t = fs.len() as u64;
        let t_stage = stats
            .per_group_cycles
            .iter()
            .map(|c| c.div_ceil(t))
            .max()
            .unwrap();
        assert_eq!(
            stats.pipelined_cycle_estimate(t),
            (groups.len() as u64 - 1 + t) * t_stage
        );
        assert_eq!(stats.pipelined_cycle_estimate(0), stats.cycles);
    }

    #[test]
    fn network_clip_rejects_mismatched_frames() {
        let net = tiny_network(); // expects (1, 8, 8) input
        let s = MultiCoreScheduler::new(2, SimConfig::default());
        let mut state = net.init_state().unwrap();
        let wrong = vec![SpikePlane::zeros(1, 16, 16)];
        assert!(s.run_network_clip(&net, &wrong, &mut state).is_err());
    }

    #[test]
    fn scheduled_engine_resets_between_clips() {
        let net = tiny_network();
        let fs: Vec<SpikePlane> = {
            let mut rng = SplitMix64::new(23);
            (0..2)
                .map(|_| {
                    let mut p = SpikePlane::zeros(1, 8, 8);
                    for i in 0..p.len() {
                        if rng.chance(0.25) {
                            p.as_mut_slice()[i] = 1;
                        }
                    }
                    p
                })
                .collect()
        };
        let mut e =
            ScheduledEngine::new(net, MultiCoreScheduler::new(2, SimConfig::default())).unwrap();
        let a = e.infer(&fs).unwrap();
        let b = e.infer(&fs).unwrap();
        // identical clips on reset state -> identical simulated run
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.run.spikes, b.run.spikes);
        assert_eq!(a.run.synops, b.run.synops);
    }

    #[test]
    fn throughput_scales_with_cores() {
        // 72 output channels at 4-bit: one core needs 2 weight passes
        // (36 parallel channels max); two cores split to 1 pass each.
        let l = layer(72);
        let fs = frames(2);
        let mut cycles = Vec::new();
        for n in [1usize, 2] {
            let s = MultiCoreScheduler::new(
                n,
                SimConfig::timing_only(crate::quant::Precision::W4V7),
            );
            let mut state = Mat::zeros(36, 72);
            let (_, st) = s.run_layer(&l, &fs, &mut state).unwrap();
            cycles.push(st.cycles);
        }
        assert!(cycles[1] < cycles[0], "{cycles:?}");
    }
}
