//! Multi-core scheduler (paper §II-E: "easily scalable to a multi-core
//! architecture where each core can process independent output neurons
//! in parallel, increasing throughput without additional data
//! movement").
//!
//! Output channels are partitioned across cores; each core runs the
//! same input stream against its channel slice. Host-side execution
//! uses real threads (one per simulated core); simulated time is the
//! max over cores, energy the sum (plus idle leakage on the laggards).

use crate::error::{Error, Result};
use crate::sim::config::SimConfig;
use crate::sim::core::SpidrCore;
use crate::sim::stats::RunStats;
use crate::snn::layer::Layer;
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

/// Multi-core scheduler over `num_cores` SpiDR cores.
#[derive(Debug, Clone)]
pub struct MultiCoreScheduler {
    /// Cores available.
    pub num_cores: usize,
    /// Per-core configuration.
    pub cfg: SimConfig,
}

/// Multi-core run result.
#[derive(Debug, Clone)]
pub struct MultiCoreStats {
    /// Simulated makespan (max over cores).
    pub cycles: u64,
    /// Total energy (sum of dynamic over cores; leakage over all
    /// cores for the full makespan).
    pub run: RunStats,
    /// Per-core cycle counts (load-balance diagnostics).
    pub per_core_cycles: Vec<u64>,
}

impl MultiCoreScheduler {
    /// New scheduler.
    pub fn new(num_cores: usize, cfg: SimConfig) -> Self {
        MultiCoreScheduler { num_cores, cfg }
    }

    /// Partition output channels `0..k` across cores (contiguous,
    /// balanced).
    pub fn partition_channels(&self, k: usize) -> Vec<(usize, usize)> {
        let n = self.num_cores.min(k).max(1);
        let base = k / n;
        let extra = k % n;
        let mut out = Vec::with_capacity(n);
        let mut lo = 0;
        for i in 0..n {
            let len = base + usize::from(i < extra);
            out.push((lo, lo + len));
            lo += len;
        }
        out
    }

    /// Run one layer's timesteps across cores (channel-parallel).
    ///
    /// `state` is the full `(M, K)` Vmem bank; each core updates its
    /// channel slice. Output planes are merged across cores.
    pub fn run_layer(
        &self,
        layer: &Layer,
        inputs: &[SpikePlane],
        state: &mut Mat,
    ) -> Result<(Vec<SpikePlane>, MultiCoreStats)> {
        let k = layer.out_shape.0;
        let parts = self.partition_channels(k);
        let weights = layer
            .weights
            .as_ref()
            .ok_or_else(|| Error::mapping("pool layer on scheduler"))?;
        let (m_total, _) = layer.vmem_shape()?;

        // Build per-core sub-layers (channel slices of the weights,
        // via row-slice block copies — §Perf).
        let mut jobs = Vec::new();
        for &(ks, ke) in &parts {
            let mut sub = layer.clone();
            sub.weights = Some(weights.submatrix(0, weights.rows, ks, ke));
            sub.out_shape = (ke - ks, layer.out_shape.1, layer.out_shape.2);
            // initial sub-state from the big bank
            let sub_state = state.submatrix(0, m_total, ks, ke);
            jobs.push((sub, sub_state, ks, ke));
        }

        // Host-parallel execution, one thread per core.
        let cfg = self.cfg;
        let results: Vec<(Vec<SpikePlane>, crate::sim::core::LayerStats, Mat, usize, usize)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = jobs
                    .into_iter()
                    .map(|(sub, mut sub_state, ks, ke)| {
                        let inputs = &inputs;
                        scope.spawn(move || {
                            let core = SpidrCore::new(cfg);
                            let (out, stats) =
                                core.run_layer(&sub, inputs, &mut sub_state)?;
                            Ok::<_, crate::error::Error>((out, stats, sub_state, ks, ke))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("core thread panicked"))
                    .collect::<Result<Vec<_>>>()
            })?;

        // Merge: outputs, state slices, stats.
        let (ko, ho, wo) = layer.out_shape;
        let mut outputs: Vec<SpikePlane> = (0..inputs.len())
            .map(|_| SpikePlane::zeros(ko, ho, wo))
            .collect();
        let mut run = RunStats::default();
        let mut per_core_cycles = Vec::new();
        let mut makespan = 0u64;
        for (out, stats, sub_state, ks, ke) in results {
            for (t, plane) in out.iter().enumerate() {
                for (c, kk) in (ks..ke).enumerate() {
                    for y in 0..ho {
                        for x in 0..wo {
                            if plane.get(c, y, x) != 0 {
                                outputs[t].set(kk, y, x, 1);
                            }
                        }
                    }
                }
            }
            for m in 0..m_total {
                for (c, kk) in (ks..ke).enumerate() {
                    state.set(m, kk, sub_state.get(m, c));
                }
            }
            per_core_cycles.push(stats.run.cycles);
            makespan = makespan.max(stats.run.cycles);
            // dense_synops / spikes / cells are per-layer quantities;
            // merge energies and op counts, then fix telemetry below.
            run.energy.add(&stats.run.energy);
            run.macro_ops += stats.run.macro_ops;
            run.synops += stats.run.synops;
            run.parity_switches += stats.run.parity_switches;
        }
        run.cycles = makespan;
        run.dense_synops = layer.dense_synops() * inputs.len() as u64;
        for inp in inputs {
            run.spikes += inp.count_spikes();
            run.cells += inp.len() as u64;
        }
        // idle cores leak for the full makespan
        let leak_scale = (cfg.corner.voltage / 0.9).powi(2);
        run.energy.leakage = self.num_cores as f64
            * cfg.energy.p_leak_mw
            * leak_scale
            * cfg.corner.period_ns()
            * makespan as f64;

        Ok((
            outputs,
            MultiCoreStats {
                cycles: makespan,
                run,
                per_core_cycles,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::SplitMix64;
    use crate::snn::layer::NeuronConfig;

    fn layer(out_ch: usize) -> Layer {
        let mut w = Mat::zeros(18, out_ch);
        for f in 0..18 {
            for k in 0..out_ch {
                w.set(f, k, ((f * 3 + k) % 7) as i32 - 3);
            }
        }
        Layer::conv((2, 6, 6), out_ch, 3, 3, 1, 1, w,
                    NeuronConfig { theta: 4, ..Default::default() }, false)
            .unwrap()
    }

    fn frames(t: usize) -> Vec<SpikePlane> {
        let mut rng = SplitMix64::new(3);
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(2, 6, 6);
                for i in 0..p.len() {
                    if rng.chance(0.25) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    #[test]
    fn partition_is_balanced_and_complete() {
        let s = MultiCoreScheduler::new(4, SimConfig::default());
        let parts = s.partition_channels(10);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|(a, b)| b - a).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn multicore_matches_single_core_function() {
        let l = layer(8);
        let fs = frames(2);

        let single = MultiCoreScheduler::new(1, SimConfig::default());
        let mut state1 = Mat::zeros(36, 8);
        let (out1, st1) = single.run_layer(&l, &fs, &mut state1).unwrap();

        let quad = MultiCoreScheduler::new(4, SimConfig::default());
        let mut state4 = Mat::zeros(36, 8);
        let (out4, st4) = quad.run_layer(&l, &fs, &mut state4).unwrap();

        assert_eq!(state1.as_slice(), state4.as_slice());
        for (a, b) in out1.iter().zip(&out4) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        // more cores -> shorter makespan (or equal for degenerate work)
        assert!(st4.cycles <= st1.cycles);
        assert_eq!(st4.per_core_cycles.len(), 4);
    }

    #[test]
    fn throughput_scales_with_cores() {
        // 72 output channels at 4-bit: one core needs 2 weight passes
        // (36 parallel channels max); two cores split to 1 pass each.
        let l = layer(72);
        let fs = frames(2);
        let mut cycles = Vec::new();
        for n in [1usize, 2] {
            let s = MultiCoreScheduler::new(
                n,
                SimConfig::timing_only(crate::quant::Precision::W4V7),
            );
            let mut state = Mat::zeros(36, 72);
            let (_, st) = s.run_layer(&l, &fs, &mut state).unwrap();
            cycles.push(st.cycles);
        }
        assert!(cycles[1] < cycles[0], "{cycles:?}");
    }
}
