//! Multi-core scheduler (paper §II-E: "easily scalable to a multi-core
//! architecture where each core can process independent output neurons
//! in parallel, increasing throughput without additional data
//! movement").
//!
//! Output channels are partitioned across cores; each core runs the
//! same input stream against its channel slice. Host-side execution
//! uses real threads (one per simulated core); simulated time is the
//! max over cores, energy the sum (plus idle leakage on the laggards).
//!
//! For the serving tier the same partitioning generalizes one level
//! up: [`MultiCoreScheduler::partition_layer_groups`] shards a
//! multi-layer network's stateful layers into contiguous,
//! cost-balanced groups — the layer-stationary placement a pool
//! worker keeps resident — and [`ScheduledEngine`] adapts whole-clip
//! multi-core execution to the [`Engine`] trait so the pool can wrap
//! simulated cores directly (DESIGN.md §Serve).

use crate::error::{Error, Result};
use crate::sim::config::SimConfig;
use crate::sim::core::SpidrCore;
use crate::sim::stats::RunStats;
use crate::snn::layer::{Layer, LayerKind};
use crate::snn::network::{pool_step, Network, NetworkState};
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

use super::server::Engine;

/// Multi-core scheduler over `num_cores` SpiDR cores.
#[derive(Debug, Clone)]
pub struct MultiCoreScheduler {
    /// Cores available.
    pub num_cores: usize,
    /// Per-core configuration.
    pub cfg: SimConfig,
}

/// Multi-core run result.
#[derive(Debug, Clone)]
pub struct MultiCoreStats {
    /// Simulated makespan (max over cores).
    pub cycles: u64,
    /// Total energy (sum of dynamic over cores; leakage over all
    /// cores for the full makespan).
    pub run: RunStats,
    /// Per-core cycle counts (load-balance diagnostics).
    pub per_core_cycles: Vec<u64>,
}

impl MultiCoreScheduler {
    /// New scheduler.
    pub fn new(num_cores: usize, cfg: SimConfig) -> Self {
        MultiCoreScheduler { num_cores, cfg }
    }

    /// Partition output channels `0..k` across cores (contiguous,
    /// balanced).
    pub fn partition_channels(&self, k: usize) -> Vec<(usize, usize)> {
        partition(k, self.num_cores)
    }

    /// Plan how a network's **stateful layers** would shard into
    /// contiguous groups, one per core/pool-worker, balancing the
    /// per-layer dense-synaptic-op cost greedily — the
    /// layer-stationary analogue of [`Self::partition_channels`].
    /// Today's pool workers each keep the whole network resident and
    /// this plan feeds placement diagnostics (`examples/serving.rs`);
    /// it becomes the actual placement when layer groups move to
    /// separate processes/hosts (ROADMAP "Cross-process sharding",
    /// DESIGN.md §Serve). Ranges index `stateful_layers()` order.
    pub fn partition_layer_groups(&self, network: &Network) -> Vec<(usize, usize)> {
        let costs: Vec<u64> = network
            .stateful_layers()
            .map(|l| l.dense_synops().max(1))
            .collect();
        let s = costs.len();
        if s == 0 {
            return Vec::new();
        }
        let n = self.num_cores.min(s).max(1);
        let total: u64 = costs.iter().sum();
        let mut groups = Vec::with_capacity(n);
        let mut lo = 0usize;
        let mut acc = 0u64;
        let mut served = 0u64;
        for (i, &c) in costs.iter().enumerate() {
            acc += c;
            let groups_left = n - groups.len(); // incl. the open group
            if groups_left == 1 {
                continue; // the last group swallows the tail
            }
            let layers_left = s - i - 1;
            // Close the open group once it reaches its fair share of
            // the remaining cost — or when the remaining layers are
            // only just enough to give every later group one layer.
            // Never close unless each later group can still get one.
            let fair = (total - served).div_ceil(groups_left as u64);
            if layers_left >= groups_left - 1 && (acc >= fair || layers_left == groups_left - 1) {
                groups.push((lo, i + 1));
                lo = i + 1;
                served += acc;
                acc = 0;
            }
        }
        groups.push((lo, s));
        groups
    }

    /// Run one layer's timesteps across cores (channel-parallel).
    ///
    /// `state` is the full `(M, K)` Vmem bank; each core updates its
    /// channel slice. Output planes are merged across cores.
    pub fn run_layer(
        &self,
        layer: &Layer,
        inputs: &[SpikePlane],
        state: &mut Mat,
    ) -> Result<(Vec<SpikePlane>, MultiCoreStats)> {
        let k = layer.out_shape.0;
        let parts = self.partition_channels(k);
        let weights = layer
            .weights
            .as_ref()
            .ok_or_else(|| Error::mapping("pool layer on scheduler"))?;
        let (m_total, _) = layer.vmem_shape()?;

        // Build per-core sub-layers (channel slices of the weights,
        // via row-slice block copies — §Perf).
        let mut jobs = Vec::new();
        for &(ks, ke) in &parts {
            let mut sub = layer.clone();
            sub.weights = Some(weights.submatrix(0, weights.rows, ks, ke));
            sub.out_shape = (ke - ks, layer.out_shape.1, layer.out_shape.2);
            // initial sub-state from the big bank
            let sub_state = state.submatrix(0, m_total, ks, ke);
            jobs.push((sub, sub_state, ks, ke));
        }

        // Host-parallel execution, one thread per core.
        let cfg = self.cfg;
        let results: Vec<(Vec<SpikePlane>, crate::sim::core::LayerStats, Mat, usize, usize)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = jobs
                    .into_iter()
                    .map(|(sub, mut sub_state, ks, ke)| {
                        let inputs = &inputs;
                        scope.spawn(move || {
                            let core = SpidrCore::new(cfg);
                            let (out, stats) =
                                core.run_layer(&sub, inputs, &mut sub_state)?;
                            Ok::<_, crate::error::Error>((out, stats, sub_state, ks, ke))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("core thread panicked"))
                    .collect::<Result<Vec<_>>>()
            })?;

        // Merge: outputs, state slices, stats.
        let (ko, ho, wo) = layer.out_shape;
        let mut outputs: Vec<SpikePlane> = (0..inputs.len())
            .map(|_| SpikePlane::zeros(ko, ho, wo))
            .collect();
        let mut run = RunStats::default();
        let mut per_core_cycles = Vec::new();
        let mut makespan = 0u64;
        for (out, stats, sub_state, ks, ke) in results {
            for (t, plane) in out.iter().enumerate() {
                for (c, kk) in (ks..ke).enumerate() {
                    for y in 0..ho {
                        for x in 0..wo {
                            if plane.get(c, y, x) != 0 {
                                outputs[t].set(kk, y, x, 1);
                            }
                        }
                    }
                }
            }
            for m in 0..m_total {
                for (c, kk) in (ks..ke).enumerate() {
                    state.set(m, kk, sub_state.get(m, c));
                }
            }
            per_core_cycles.push(stats.run.cycles);
            makespan = makespan.max(stats.run.cycles);
            // dense_synops / spikes / cells are per-layer quantities;
            // merge energies and op counts, then fix telemetry below.
            run.energy.add(&stats.run.energy);
            run.macro_ops += stats.run.macro_ops;
            run.synops += stats.run.synops;
            run.parity_switches += stats.run.parity_switches;
        }
        run.cycles = makespan;
        run.dense_synops = layer.dense_synops() * inputs.len() as u64;
        for inp in inputs {
            run.spikes += inp.count_spikes();
            run.cells += inp.len() as u64;
        }
        // idle cores leak for the full makespan
        let leak_scale = (cfg.corner.voltage / 0.9).powi(2);
        run.energy.leakage = self.num_cores as f64
            * cfg.energy.p_leak_mw
            * leak_scale
            * cfg.corner.period_ns()
            * makespan as f64;

        Ok((
            outputs,
            MultiCoreStats {
                cycles: makespan,
                run,
                per_core_cycles,
            },
        ))
    }

    /// Run a whole multi-layer clip, sharding **every stateful layer's
    /// output channels** across the simulated cores (pool layers run
    /// in the loader, as on silicon). Layers execute in sequence —
    /// layer `l` at timestep `t` consumes layer `l−1`'s spikes — so
    /// simulated cycles add across layers while each layer's makespan
    /// is the max over its channel shards. `state` must come from
    /// [`Network::init_state`] (reset it between independent clips).
    pub fn run_network_clip(
        &self,
        network: &Network,
        frames: &[SpikePlane],
        state: &mut NetworkState,
    ) -> Result<(Vec<SpikePlane>, MultiCoreStats)> {
        let (c0, h0, w0) = network
            .layers
            .first()
            .ok_or_else(|| Error::config("empty network"))?
            .in_shape;
        for f in frames {
            if f.shape() != (c0, h0, w0) {
                return Err(Error::shape(format!(
                    "frame shape {:?} != network input {:?}",
                    f.shape(),
                    (c0, h0, w0)
                )));
            }
        }
        let mut planes: Vec<SpikePlane> = frames.to_vec();
        let mut total = MultiCoreStats {
            cycles: 0,
            run: RunStats::default(),
            per_core_cycles: Vec::new(),
        };
        let mut si = 0;
        for layer in &network.layers {
            match layer.kind {
                LayerKind::Pool => {
                    planes = planes.iter().map(|p| pool_step(layer, p)).collect();
                }
                LayerKind::Conv | LayerKind::Fc => {
                    let (out, stats) =
                        self.run_layer(layer, &planes, &mut state.vmems[si])?;
                    total.cycles += stats.cycles;
                    total.run.add(&stats.run);
                    for (i, c) in stats.per_core_cycles.iter().enumerate() {
                        if i >= total.per_core_cycles.len() {
                            total.per_core_cycles.push(0);
                        }
                        total.per_core_cycles[i] += c;
                    }
                    planes = out;
                    si += 1;
                }
            }
        }
        Ok((planes, total))
    }
}

/// Contiguous balanced partition of `0..k` into at most `n` ranges.
fn partition(k: usize, n: usize) -> Vec<(usize, usize)> {
    let n = n.min(k).max(1);
    let base = k / n;
    let extra = k % n;
    let mut out = Vec::with_capacity(n);
    let mut lo = 0;
    for i in 0..n {
        let len = base + usize::from(i < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// [`Engine`] adapter over the multi-core scheduler: each clip is an
/// independent inference of a multi-layer network, with every layer's
/// channels sharded across the scheduler's simulated cores. This is
/// the engine a pool worker wraps to put the cycle-level simulator on
/// the sharded request path (DESIGN.md §Serve); its Vmem state is
/// allocated once and zeroed between clips.
#[derive(Debug, Clone)]
pub struct ScheduledEngine {
    // Private: `state` was sized for `network` at construction, so
    // swapping either field independently would desync them.
    network: Network,
    scheduler: MultiCoreScheduler,
    state: NetworkState,
}

impl ScheduledEngine {
    /// Build an engine around a workload (allocates state once).
    pub fn new(network: Network, scheduler: MultiCoreScheduler) -> Result<Self> {
        let state = network.init_state()?;
        Ok(ScheduledEngine {
            network,
            scheduler,
            state,
        })
    }

    /// The workload this engine serves.
    pub fn network(&self) -> &Network {
        &self.network
    }

    /// The scheduler sharding each layer across simulated cores.
    pub fn scheduler(&self) -> &MultiCoreScheduler {
        &self.scheduler
    }
}

impl Engine for ScheduledEngine {
    type Output = MultiCoreStats;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<MultiCoreStats> {
        self.state.reset();
        let (_, stats) =
            self.scheduler
                .run_network_clip(&self.network, clip, &mut self.state)?;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::SplitMix64;
    use crate::snn::layer::NeuronConfig;

    fn layer(out_ch: usize) -> Layer {
        let mut w = Mat::zeros(18, out_ch);
        for f in 0..18 {
            for k in 0..out_ch {
                w.set(f, k, ((f * 3 + k) % 7) as i32 - 3);
            }
        }
        Layer::conv((2, 6, 6), out_ch, 3, 3, 1, 1, w,
                    NeuronConfig { theta: 4, ..Default::default() }, false)
            .unwrap()
    }

    fn frames(t: usize) -> Vec<SpikePlane> {
        let mut rng = SplitMix64::new(3);
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(2, 6, 6);
                for i in 0..p.len() {
                    if rng.chance(0.25) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    #[test]
    fn partition_is_balanced_and_complete() {
        let s = MultiCoreScheduler::new(4, SimConfig::default());
        let parts = s.partition_channels(10);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|(a, b)| b - a).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn multicore_matches_single_core_function() {
        let l = layer(8);
        let fs = frames(2);

        let single = MultiCoreScheduler::new(1, SimConfig::default());
        let mut state1 = Mat::zeros(36, 8);
        let (out1, st1) = single.run_layer(&l, &fs, &mut state1).unwrap();

        let quad = MultiCoreScheduler::new(4, SimConfig::default());
        let mut state4 = Mat::zeros(36, 8);
        let (out4, st4) = quad.run_layer(&l, &fs, &mut state4).unwrap();

        assert_eq!(state1.as_slice(), state4.as_slice());
        for (a, b) in out1.iter().zip(&out4) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        // more cores -> shorter makespan (or equal for degenerate work)
        assert!(st4.cycles <= st1.cycles);
        assert_eq!(st4.per_core_cycles.len(), 4);
    }

    fn tiny_network() -> Network {
        use crate::quant::Precision;
        use crate::snn::network::NetworkBuilder;
        let mut w1 = Mat::zeros(9, 4);
        for f in 0..9 {
            for k in 0..4 {
                w1.set(f, k, ((f + 2 * k) % 5) as i32 - 2);
            }
        }
        let w2 = Mat::zeros(4 * 4 * 4, 2);
        NetworkBuilder::new("sched-tiny", Precision::W4V7, 2, (1, 8, 8))
            .conv3x3(4, w1, NeuronConfig { theta: 3, ..Default::default() }, false)
            .unwrap()
            .pool(2, 2)
            .fc(2, w2, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    #[test]
    fn layer_groups_cover_all_stateful_layers_contiguously() {
        let net = tiny_network(); // 2 stateful layers (conv, fc)
        for cores in [1usize, 2, 3, 8] {
            let s = MultiCoreScheduler::new(cores, SimConfig::default());
            let groups = s.partition_layer_groups(&net);
            assert_eq!(groups.len(), cores.min(2));
            assert_eq!(groups[0].0, 0);
            assert_eq!(groups.last().unwrap().1, 2);
            for w in groups.windows(2) {
                assert_eq!(w[0].1, w[1].0, "groups must be contiguous");
            }
            assert!(groups.iter().all(|(a, b)| a < b), "no empty group");
        }
    }

    #[test]
    fn layer_groups_balance_cost() {
        // 6 equal-cost stateful layers over 3 workers -> 2 each.
        use crate::quant::Precision;
        use crate::snn::network::NetworkBuilder;
        let mut b = NetworkBuilder::new("six", Precision::W4V7, 1, (2, 6, 6));
        for i in 0..6 {
            // the builder requires an accumulate output layer
            b = b
                .conv3x3(2, Mat::zeros(18, 2), NeuronConfig::default(), i == 5)
                .unwrap();
        }
        let net = b.build().unwrap();
        let s = MultiCoreScheduler::new(3, SimConfig::default());
        let groups = s.partition_layer_groups(&net);
        assert_eq!(groups, vec![(0, 2), (2, 4), (4, 6)]);
    }

    #[test]
    fn network_clip_matches_reference_executor() {
        let net = tiny_network();
        let fs: Vec<SpikePlane> = {
            let mut rng = SplitMix64::new(17);
            (0..2)
                .map(|_| {
                    let mut p = SpikePlane::zeros(1, 8, 8);
                    for i in 0..p.len() {
                        if rng.chance(0.3) {
                            p.as_mut_slice()[i] = 1;
                        }
                    }
                    p
                })
                .collect()
        };

        // reference trajectory
        let mut ref_state = net.init_state().unwrap();
        for f in &fs {
            net.step(f, &mut ref_state).unwrap();
        }

        // channel-sharded multi-core trajectory
        let s = MultiCoreScheduler::new(3, SimConfig::default());
        let mut state = net.init_state().unwrap();
        let (_, stats) = s.run_network_clip(&net, &fs, &mut state).unwrap();

        for (a, b) in ref_state.vmems.iter().zip(&state.vmems) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        assert!(stats.cycles > 0);
        assert!(!stats.per_core_cycles.is_empty());
    }

    #[test]
    fn network_clip_rejects_mismatched_frames() {
        let net = tiny_network(); // expects (1, 8, 8) input
        let s = MultiCoreScheduler::new(2, SimConfig::default());
        let mut state = net.init_state().unwrap();
        let wrong = vec![SpikePlane::zeros(1, 16, 16)];
        assert!(s.run_network_clip(&net, &wrong, &mut state).is_err());
    }

    #[test]
    fn scheduled_engine_resets_between_clips() {
        let net = tiny_network();
        let fs: Vec<SpikePlane> = {
            let mut rng = SplitMix64::new(23);
            (0..2)
                .map(|_| {
                    let mut p = SpikePlane::zeros(1, 8, 8);
                    for i in 0..p.len() {
                        if rng.chance(0.25) {
                            p.as_mut_slice()[i] = 1;
                        }
                    }
                    p
                })
                .collect()
        };
        let mut e =
            ScheduledEngine::new(net, MultiCoreScheduler::new(2, SimConfig::default()))
                .unwrap();
        let a = e.infer(&fs).unwrap();
        let b = e.infer(&fs).unwrap();
        // identical clips on reset state -> identical simulated run
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.run.spikes, b.run.spikes);
        assert_eq!(a.run.synops, b.run.synops);
    }

    #[test]
    fn throughput_scales_with_cores() {
        // 72 output channels at 4-bit: one core needs 2 weight passes
        // (36 parallel channels max); two cores split to 1 pass each.
        let l = layer(72);
        let fs = frames(2);
        let mut cycles = Vec::new();
        for n in [1usize, 2] {
            let s = MultiCoreScheduler::new(
                n,
                SimConfig::timing_only(crate::quant::Precision::W4V7),
            );
            let mut state = Mat::zeros(36, 72);
            let (_, st) = s.run_layer(&l, &fs, &mut state).unwrap();
            cycles.push(st.cycles);
        }
        assert!(cycles[1] < cycles[0], "{cycles:?}");
    }
}
