//! L3 coordinator: the request path. Layer mapping (paper Fig. 12),
//! network compilation onto the simulated core, multi-core channel
//! scheduling, streaming event ingestion with backpressure, the
//! sharded serving pool, and metrics. Python never runs here — the
//! functional math comes from either the cycle simulator, the
//! functional reference executor, or the AOT PJRT artifacts.
//!
//! Request path at a glance (README.md has the full diagram):
//!
//! ```text
//! events ─► ingest (bin) ─► dispatch ─► worker pool ─► reorder ─► responses
//!                           bounded       N engines     by seq
//!                           inboxes      (1 core each)
//! ```

pub mod compiler;
pub mod mapper;
pub mod metrics;
pub mod pool;
pub mod scheduler;
pub mod server;

pub use compiler::{ClipReport, CompiledNetwork, NetworkCompiler};
pub use mapper::{LayerMapping, Mapper};
pub use metrics::{Metrics, WorkerMetrics};
pub use pool::{run_pool, ClipJob, CompletedClip, PoolConfig, PoolRun, StealPolicy};
pub use scheduler::{MultiCoreScheduler, MultiCoreStats, ScheduledEngine};
pub use server::{Engine, InferenceServer, ReferenceEngine, Response, ServerConfig};
