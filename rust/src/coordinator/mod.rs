//! L3 coordinator: the request path. Layer mapping (paper Fig. 12),
//! network compilation onto the simulated core, multi-core channel
//! scheduling, streaming event ingestion with backpressure, and
//! metrics. Python never runs here — the functional math comes from
//! either the cycle simulator or the AOT PJRT artifacts.

pub mod compiler;
pub mod mapper;
pub mod metrics;
pub mod scheduler;
pub mod server;

pub use compiler::{ClipReport, CompiledNetwork, NetworkCompiler};
pub use mapper::{LayerMapping, Mapper};
pub use metrics::Metrics;
pub use scheduler::{MultiCoreScheduler, MultiCoreStats};
pub use server::{Engine, InferenceServer, Response, ServerConfig};
