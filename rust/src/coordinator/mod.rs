//! L3 coordinator: the request path. Layer mapping (paper Fig. 12),
//! network compilation onto the simulated core, multi-core channel
//! scheduling, streaming event ingestion with backpressure, the
//! sharded serving pool, and metrics. Python never runs here — the
//! functional math comes from either the cycle simulator, the
//! functional reference executor, or the AOT PJRT artifacts.
//!
//! Request path at a glance (README.md has the full diagram):
//!
//! ```text
//! events ─► ingest (bin) ─► dispatch ─► worker pool ─► reorder ─► responses
//!                           bounded       N engines     by seq
//!                           inboxes      (1 core each)
//! ```
//!
//! Inside a worker, a clip runs on one of five engines: the
//! sequential functional reference, the cycle-level simulator, the
//! timestep-staged layer-group pipeline ([`pipeline`], DESIGN.md
//! §Pipeline) — stage `g` steps timestep `t` while stage `g−1` steps
//! `t+1`, bounded spike-frame channels handshaking between them — the
//! distributed shard engine (`crate::net`, DESIGN.md §Distributed),
//! the same staging chained across processes/hosts over a binary wire
//! protocol — or the batch-parallel bit-plane engine ([`batch`],
//! DESIGN.md §Perf), which packs up to 64 queued clips into `u64`
//! spike lanes and sweeps the CIM rows once per batch. Under
//! `PoolConfig::sizing`, the pool itself grows and shrinks with the
//! load between a min/max worker count.

pub mod batch;
pub mod compiler;
pub mod mapper;
pub mod metrics;
pub mod pipeline;
pub mod pool;
pub mod scheduler;
pub mod server;

pub use batch::{BatchConfig, BatchedEngine};
pub use compiler::{ClipReport, CompiledNetwork, NetworkCompiler};
pub use mapper::{LayerMapping, Mapper};
pub use metrics::{Metrics, StageMetrics, WorkerMetrics};
pub use pipeline::{run_pipeline_clip, FunctionalEngine, PipelineConfig, PipelinedEngine};
pub use pool::{
    run_pool, ClipJob, CompletedClip, Dispatch, Fetched, PoolConfig, PoolRun, SharedQueue,
    StealPolicy,
};
pub use scheduler::{
    balanced_partition, plan_layer_groups, MultiCoreScheduler, MultiCoreStats, ScheduledEngine,
};
pub use server::{Engine, InferenceServer, ReferenceEngine, Response, ServerConfig};
