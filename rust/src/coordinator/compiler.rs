//! Network compiler: turns a [`Network`] into an execution plan over
//! the simulated core and runs whole clips through it.

use crate::error::Result;
use crate::sim::config::SimConfig;
use crate::sim::core::{LayerStats, SpidrCore};
use crate::sim::stats::RunStats;
use crate::snn::layer::LayerKind;
use crate::snn::network::{pool_step, Network, NetworkState};
use crate::snn::spikes::SpikePlane;

use super::mapper::{LayerMapping, Mapper};
use super::server::Engine;

/// A compiled network: per-stateful-layer mappings, ready to execute.
///
/// The public fields are snapshots taken together by
/// [`NetworkCompiler::compile`]; mutating one (e.g. swapping
/// `network`) desyncs the others — recompile instead.
#[derive(Debug, Clone)]
pub struct CompiledNetwork {
    /// The workload.
    pub network: Network,
    /// Mapping per stateful layer (indexed like `stateful_layers()`).
    pub mappings: Vec<LayerMapping>,
    /// Simulation configuration.
    pub cfg: SimConfig,
    /// Vmem state reused by the `Engine` path (lazily allocated,
    /// zeroed per clip so every request is an independent inference).
    engine_state: Option<NetworkState>,
}

/// Clip-level execution report.
#[derive(Debug, Clone)]
pub struct ClipReport {
    /// Aggregate over all layers.
    pub total: RunStats,
    /// Per-stateful-layer stats.
    pub per_layer: Vec<LayerStats>,
    /// Per-stateful-layer mean input sparsity.
    pub layer_sparsity: Vec<f64>,
}

/// The compiler.
pub struct NetworkCompiler;

impl NetworkCompiler {
    /// Validate and map every stateful layer of a network.
    ///
    /// The network's precision operating point is authoritative: it
    /// overrides `cfg.precision` so the simulated adder-chain width
    /// always matches the quantization the weights were produced at.
    pub fn compile(network: Network, mut cfg: SimConfig) -> Result<CompiledNetwork> {
        cfg.precision = network.precision;
        let mappings = Mapper::new(cfg.precision).map_network(&network)?;
        Ok(CompiledNetwork {
            network,
            mappings,
            cfg,
            engine_state: None,
        })
    }
}

/// A compiled network is directly usable as a serving-pool engine:
/// each clip is an independent inference on the simulated core (state
/// is freshly initialized per clip), reporting the full cycle/energy
/// telemetry. Pool workers clone one compiled network each
/// (weights stay worker-resident; DESIGN.md §Serve).
impl CompiledNetwork {
    /// True when every bank of `state` matches the current network's
    /// stateful-layer shapes — guards the engine-state cache against
    /// `network` being swapped through the public field between calls.
    fn state_shape_matches(&self, state: &NetworkState) -> bool {
        let mut n = 0;
        for layer in self.network.stateful_layers() {
            let Ok((m, k)) = layer.vmem_shape() else {
                return false;
            };
            match state.vmems.get(n) {
                Some(bank) if bank.rows == m && bank.cols == k => {}
                _ => return false,
            }
            n += 1;
        }
        n == state.vmems.len()
    }
}

impl Engine for CompiledNetwork {
    type Output = ClipReport;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<ClipReport> {
        // Take the cached state out so `run_clip(&self, ...)` can
        // borrow self while the state is mutated, then put it back.
        // Rebuild instead of reusing if its shape no longer matches.
        let mut state = match self.engine_state.take() {
            Some(mut s) if self.state_shape_matches(&s) => {
                s.reset();
                s
            }
            _ => self.network.init_state()?,
        };
        let report = self.run_clip(clip, &mut state);
        self.engine_state = Some(state);
        report
    }
}

impl CompiledNetwork {
    /// Execute a full clip on the simulated core, layer by layer
    /// (weights are stationary per layer; the input is re-streamed per
    /// extra channel pass, exactly as the silicon would).
    ///
    /// `state` carries full Vmems across clips (reset it between
    /// independent clips).
    pub fn run_clip(
        &self,
        frames: &[SpikePlane],
        state: &mut NetworkState,
    ) -> Result<ClipReport> {
        let core = SpidrCore::new(self.cfg);
        let mut planes: Vec<SpikePlane> = frames.to_vec();
        let mut per_layer = Vec::new();
        let mut layer_sparsity = Vec::new();
        let mut total = RunStats::default();
        let mut si = 0;
        for layer in &self.network.layers {
            match layer.kind {
                LayerKind::Pool => {
                    planes = planes.iter().map(|p| pool_step(layer, p)).collect();
                }
                LayerKind::Conv | LayerKind::Fc => {
                    let (outputs, stats) =
                        core.run_layer(layer, &planes, &mut state.vmems[si])?;
                    layer_sparsity.push(stats.run.sparsity());
                    total.add(&stats.run);
                    per_layer.push(stats);
                    planes = outputs;
                    si += 1;
                }
            }
        }
        total.finalize_leakage(self.cfg.corner, &self.cfg.energy);
        Ok(ClipReport {
            total,
            per_layer,
            layer_sparsity,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Precision;
    use crate::snn::layer::NeuronConfig;
    use crate::snn::network::NetworkBuilder;
    use crate::snn::tensor::Mat;

    fn tiny_network() -> Network {
        let mut w1 = Mat::zeros(9, 4);
        for f in 0..9 {
            for k in 0..4 {
                w1.set(f, k, ((f + k) % 5) as i32 - 2);
            }
        }
        let w2 = Mat::zeros(4 * 4 * 4, 2);
        NetworkBuilder::new("tiny", Precision::W4V7, 2, (1, 8, 8))
            .conv3x3(4, w1, NeuronConfig { theta: 3, ..Default::default() }, false)
            .unwrap()
            .pool(2, 2)
            .fc(2, w2, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    fn frames(density: f64, t: usize) -> Vec<SpikePlane> {
        let mut rng = crate::prop::SplitMix64::new(11);
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(1, 8, 8);
                for i in 0..p.len() {
                    if rng.chance(density) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    #[test]
    fn compile_maps_stateful_layers_only() {
        let c = NetworkCompiler::compile(tiny_network(), SimConfig::default()).unwrap();
        assert_eq!(c.mappings.len(), 2); // conv + fc, pool skipped
    }

    #[test]
    fn run_clip_matches_reference() {
        let net = tiny_network();
        let fs = frames(0.3, 2);

        // reference trajectory
        let mut ref_state = net.init_state().unwrap();
        for f in &fs {
            net.step(f, &mut ref_state).unwrap();
        }

        // simulated trajectory
        let compiled =
            NetworkCompiler::compile(net.clone(), SimConfig::default()).unwrap();
        let mut sim_state = net.init_state().unwrap();
        let report = compiled.run_clip(&fs, &mut sim_state).unwrap();

        for (a, b) in ref_state.vmems.iter().zip(&sim_state.vmems) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        assert_eq!(report.per_layer.len(), 2);
        assert!(report.total.cycles > 0);
        assert!(report.total.energy.leakage > 0.0);
    }

    #[test]
    fn sparsity_telemetry_ordered_by_layer() {
        let compiled =
            NetworkCompiler::compile(tiny_network(), SimConfig::default()).unwrap();
        let mut state = compiled.network.init_state().unwrap();
        let report = compiled.run_clip(&frames(0.2, 2), &mut state).unwrap();
        assert_eq!(report.layer_sparsity.len(), 2);
        for s in &report.layer_sparsity {
            assert!((0.0..=1.0).contains(s));
        }
    }
}
