//! Sharded serving tier: a load-balanced pool of inference workers.
//!
//! The single-engine server serializes every clip behind one engine;
//! one slow clip stalls the whole request path. The pool scales that
//! path out: N worker threads, each wrapping its **own** engine
//! instance (simulated [`SpidrCore`](crate::sim::core::SpidrCore)
//! via [`ScheduledEngine`](super::scheduler::ScheduledEngine), a
//! compiled network, or the functional reference executor), fed by a
//! work-stealing dispatch queue with **bounded per-worker inboxes**.
//!
//! Three invariants (DESIGN.md §Serve):
//!
//! * **Backpressure** — a full pool blocks the dispatcher, which
//!   blocks the bounded ingest channel, which throttles event binning.
//!   Clips are never dropped; saturation propagates to the source
//!   exactly as the chip's asynchronous handshaking stalls a producer
//!   whose consumer FIFO is full.
//! * **Ordering** — workers complete out of order (heterogeneous
//!   latencies); the emission stage holds a sequence-number reorder
//!   buffer and releases responses strictly in arrival order.
//! * **Work conservation** — under [`StealPolicy::Steal`], an idle
//!   worker drains the back of the most-loaded peer inbox, so one
//!   slow clip cannot strand queued work behind it.

use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::{Condvar, Mutex};
use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::net::coordinator::DistributedConfig;
use crate::obs::trace::{self, TraceId};
use crate::snn::spikes::SpikePlane;

use super::batch::BatchConfig;
use super::metrics::{StageMetrics, WorkerMetrics};
use super::pipeline::PipelineConfig;
use super::server::Engine;

/// How idle workers acquire work beyond their own inbox.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StealPolicy {
    /// Workers only consume their own inbox (strict affinity; a slow
    /// worker can strand clips queued behind it until it catches up).
    Pinned,
    /// Idle workers steal from the back of the most-loaded peer inbox
    /// (work-conserving; the default).
    Steal,
}

/// Dynamic pool sizing (ROADMAP "dynamic pool sizing"): let the pool
/// breathe with the load instead of pinning the worker count.
///
/// The dispatcher **grows** the pool — starting one more worker, up to
/// `max_workers` — at the exact moment it would otherwise block: every
/// active inbox full (the same queue-pressure signal
/// `WorkerMetrics::inbox_high_water` records). A worker **shrinks**
/// the pool by retiring when it has waited `shrink_idle` with every
/// inbox empty and more than `min_workers` workers alive — the
/// busy/idle split that `WorkerMetrics` tracks, applied online.
/// Retired workers report [`WorkerMetrics::retired`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolSizing {
    /// Floor: the pool never shrinks below this many workers
    /// (clamped to ≥ 1). This is also the number started up front.
    pub min_workers: usize,
    /// Ceiling: the pool never grows beyond this many workers
    /// (clamped to ≥ `min_workers`).
    pub max_workers: usize,
    /// How long a worker must sit idle, with every inbox drained,
    /// before it retires.
    pub shrink_idle: Duration,
}

impl Default for PoolSizing {
    fn default() -> Self {
        PoolSizing {
            min_workers: 1,
            max_workers: 4,
            shrink_idle: Duration::from_millis(100),
        }
    }
}

/// Serving-pool configuration, sibling of
/// [`ServerConfig`](super::server::ServerConfig).
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    /// Worker threads, each owning one engine instance (the fixed
    /// count; superseded by `sizing` when that is set).
    pub workers: usize,
    /// Bounded inbox depth per worker (backpressure window).
    pub inbox_depth: usize,
    /// Idle-worker acquisition policy.
    pub steal: StealPolicy,
    /// Select the timestep-pipelined functional engine (`Some`) over
    /// the sequential reference (`None`) when worker engines are built
    /// from this config (`FunctionalEngine::from_config`) — each
    /// worker then runs its clips through a staged layer-group
    /// pipeline of its own (DESIGN.md §Pipeline).
    pub pipeline: Option<PipelineConfig>,
    /// Select the distributed shard engine (`Some`) when worker
    /// engines are built from this config — each worker then drives
    /// its own loopback shard constellation (`net`, DESIGN.md
    /// §Distributed). Mutually exclusive with `pipeline`.
    pub distributed: Option<DistributedConfig>,
    /// Select the batched bit-plane engine (`Some`) when worker
    /// engines are built from this config — each worker then drains
    /// its own inbox behind every fetched job and sweeps the batch
    /// through the CIM rows once ([`super::batch`], DESIGN.md §Perf).
    /// Mutually exclusive with `pipeline` and `distributed`.
    pub batch: Option<BatchConfig>,
    /// Dynamic sizing between a min/max worker count (`None` keeps the
    /// fixed `workers` count).
    pub sizing: Option<PoolSizing>,
    /// Deadline-bounded batch assembly (DESIGN.md §Planner): a
    /// batch-capable worker that fetched a clip holds its filling
    /// batch up to this long, gathering only same-length stragglers
    /// from its inbox (`SharedQueue::drain_own_matching`), before
    /// dispatching. `0` keeps the legacy non-blocking drain that
    /// batches whatever is already queued regardless of clip length.
    pub deadline_us: u32,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: 4,
            inbox_depth: 2,
            steal: StealPolicy::Steal,
            pipeline: None,
            distributed: None,
            batch: None,
            sizing: None,
            deadline_us: 0,
        }
    }
}

impl PoolConfig {
    /// A pool of `workers` workers with default inbox depth and
    /// stealing enabled.
    pub fn with_workers(workers: usize) -> Self {
        PoolConfig {
            workers,
            ..PoolConfig::default()
        }
    }

    /// Maximum clips resident in the pool at once (inboxes plus one
    /// in-flight clip per worker) — the pool's backpressure bound.
    /// Under dynamic `sizing` the worker count is `max_workers`, the
    /// most the pool can grow to.
    pub fn capacity(&self) -> usize {
        let workers = match self.sizing {
            Some(s) => s.max_workers.max(s.min_workers).max(1),
            None => self.workers.max(1),
        };
        workers * (self.inbox_depth.max(1) + 1)
    }
}

/// One unit of pool work: a binned clip tagged with its arrival
/// sequence number and ingestion start time.
#[derive(Debug)]
pub struct ClipJob {
    /// Arrival order (the reorder key).
    pub seq: u64,
    /// Ingestion start (end-to-end latency reference).
    pub t0: Instant,
    /// Trace identity minted at ingest ([`TraceId::NONE`] when the
    /// clip was built outside the serve paths); every tier the clip
    /// crosses attributes its spans to this id (`obs::trace`).
    pub trace: TraceId,
    /// Binned spike frames, one per timestep.
    pub frames: Vec<SpikePlane>,
}

/// One clip completed by the pool, in emission (= arrival) order.
#[derive(Debug)]
pub struct CompletedClip<O> {
    /// Arrival sequence number.
    pub seq: u64,
    /// Engine output.
    pub output: O,
    /// End-to-end latency (ingestion start → inference done).
    pub latency: Duration,
    /// Frames in the clip.
    pub frames: u64,
    /// Worker that served the clip.
    pub worker: usize,
}

/// Result of draining a job stream through the pool.
#[derive(Debug)]
pub struct PoolRun<O> {
    /// Completed clips, reordered into arrival-sequence order.
    pub clips: Vec<CompletedClip<O>>,
    /// Per-worker counters, one entry per worker thread ever started
    /// (in spawn order). Under dynamic sizing a retired worker's slot
    /// id can be revived by a later grow, so `worker` ids may repeat
    /// across entries; `inbox_high_water` is tracked per slot.
    pub workers: Vec<WorkerMetrics>,
    /// Per-stage counters aggregated across every worker's engine
    /// (indexed by stage, each worker's stage *i* absorbed into entry
    /// *i*). Empty when worker engines expose no stages (satellite:
    /// [`InferenceServer::serve_pool`](super::server::InferenceServer::serve_pool)
    /// surfaces these in
    /// [`Metrics::stages`](super::metrics::Metrics::stages)).
    pub stages: Vec<StageMetrics>,
}

/// Everything a worker sends to the emission stage.
type WorkerResult<O> = std::result::Result<CompletedClip<O>, Error>;

/// What the dispatcher got back for one job.
///
/// Public so `tests/model.rs` can drive the dispatch/retire protocol
/// directly under the `--cfg spidr_model` checker.
pub enum Dispatch {
    /// Placed on an inbox.
    Placed,
    /// Every active inbox is full and the pool may still grow: the
    /// caller should start a worker and re-dispatch the returned job.
    Grow(ClipJob),
    /// Every worker exited or a worker reported an error (fail fast —
    /// don't grind the rest of the stream just to discard it).
    Closed,
}

/// What a worker's wait for work produced.
///
/// Public so `tests/model.rs` can drive the dispatch/retire protocol
/// directly under the `--cfg spidr_model` checker.
pub enum Fetched {
    /// A job; the flag marks a steal.
    Job(ClipJob, bool),
    /// The pool closed and drained; exit normally.
    Closed,
    /// The worker retired under dynamic sizing (already deregistered;
    /// carries its inbox high-water mark).
    Retired(usize),
}

/// Shared dispatch state: per-worker bounded inboxes guarded by one
/// mutex, with condvars for "work arrived" and "a slot freed".
/// Inboxes are appended by [`SharedQueue::start_worker`], so the pool
/// can grow mid-stream under dynamic sizing.
struct PoolState {
    /// Per-worker inboxes, each bounded by `inbox_depth`; one per
    /// worker ever started.
    inboxes: Vec<VecDeque<ClipJob>>,
    /// Queue-depth high-water mark per inbox.
    high_water: Vec<usize>,
    /// Workers that retired under dynamic sizing (their inboxes are
    /// empty and no longer receive dispatches).
    retired: Vec<bool>,
    /// No more jobs will be dispatched; workers drain and exit.
    closed: bool,
    /// A worker reported an error: stop admitting new jobs (fail
    /// fast); at most the clips already resident still complete.
    aborted: bool,
    /// Workers still running (dispatch aborts when this hits zero).
    alive: usize,
    /// Round-robin cursor breaking ties between equally loaded inboxes.
    rr: usize,
}

/// The pool's shared dispatch queue (see [`PoolState`]). Public —
/// together with [`Dispatch`] and [`Fetched`] — so the bounded-inbox
/// backpressure and dispatch-vs-retire protocols can be model-checked
/// in `tests/model.rs`; `run_pool` remains the only production
/// driver.
pub struct SharedQueue {
    state: Mutex<PoolState>,
    /// Signaled when work is enqueued or the pool closes.
    work: Condvar,
    /// Signaled when an inbox slot frees or a worker exits.
    space: Condvar,
}

impl SharedQueue {
    /// An empty queue with no workers registered.
    pub fn new() -> Self {
        SharedQueue {
            state: Mutex::new(PoolState {
                inboxes: Vec::new(),
                high_water: Vec::new(),
                retired: Vec::new(),
                closed: false,
                aborted: false,
                alive: 0,
                rr: 0,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Register one more worker and return its slot id (the caller
    /// spawns the thread). A slot freed by an earlier retirement is
    /// reused — its thread has already exited and its inbox is empty
    /// by the retire invariant — so grow/shrink churn on a long stream
    /// keeps pool state proportional to `max_workers`, not to the
    /// number of resizes.
    pub fn start_worker(&self) -> usize {
        let mut st = self.state.lock().unwrap();
        st.alive += 1;
        if let Some(slot) = st.retired.iter().position(|&r| r) {
            st.retired[slot] = false;
            debug_assert!(st.inboxes[slot].is_empty());
            return slot;
        }
        st.inboxes.push(VecDeque::new());
        st.high_water.push(0);
        st.retired.push(false);
        st.inboxes.len() - 1
    }

    /// Enqueue a job onto the least-loaded active inbox with a free
    /// slot, blocking while every inbox is full (this is the
    /// backpressure edge). When every active inbox is full and fewer
    /// than `grow_limit` workers are alive, the job comes back as
    /// [`Dispatch::Grow`] instead — the queue-pressure signal dynamic
    /// sizing grows on.
    pub fn dispatch(&self, depth: usize, job: ClipJob, grow_limit: usize) -> Dispatch {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.alive == 0 || st.aborted {
                return Dispatch::Closed;
            }
            let n = st.inboxes.len();
            let mut best: Option<usize> = None;
            for off in 0..n {
                let i = (st.rr + off) % n;
                if st.retired[i] {
                    continue;
                }
                let len = st.inboxes[i].len();
                if len < depth {
                    let better = match best {
                        None => true,
                        Some(b) => len < st.inboxes[b].len(),
                    };
                    if better {
                        best = Some(i);
                    }
                }
            }
            match best {
                Some(i) => {
                    st.inboxes[i].push_back(job);
                    if st.inboxes[i].len() > st.high_water[i] {
                        st.high_water[i] = st.inboxes[i].len();
                    }
                    st.rr = (i + 1) % n;
                    drop(st);
                    self.work.notify_all();
                    return Dispatch::Placed;
                }
                None if st.alive < grow_limit => return Dispatch::Grow(job),
                None => st = self.space.wait(st).unwrap(),
            }
        }
    }

    /// Next job for worker `me`: own inbox first, then (under
    /// [`StealPolicy::Steal`]) the back of the most-loaded peer inbox.
    /// Blocks while the pool is open and empty. With `shrink` set to
    /// `(idle, min_workers)`, a worker whose wait times out while
    /// every inbox is drained and more than `min_workers` are alive
    /// retires instead of waiting on (dynamic sizing's shrink edge).
    pub fn next(&self, me: usize, steal: StealPolicy, shrink: Option<(Duration, usize)>) -> Fetched {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.inboxes[me].pop_front() {
                drop(st);
                self.space.notify_all();
                return Fetched::Job(job, false);
            }
            if steal == StealPolicy::Steal {
                let n = st.inboxes.len();
                let mut victim: Option<usize> = None;
                for i in 0..n {
                    if i != me && !st.inboxes[i].is_empty() {
                        let better = match victim {
                            None => true,
                            Some(v) => st.inboxes[i].len() > st.inboxes[v].len(),
                        };
                        if better {
                            victim = Some(i);
                        }
                    }
                }
                if let Some(v) = victim {
                    let job = st.inboxes[v].pop_back().unwrap();
                    drop(st);
                    self.space.notify_all();
                    return Fetched::Job(job, true);
                }
            }
            if st.closed {
                return Fetched::Closed;
            }
            match shrink {
                None => st = self.work.wait(st).unwrap(),
                Some((idle, min_workers)) => {
                    let (next_st, timeout) = self.work.wait_timeout(st, idle).unwrap();
                    st = next_st;
                    // Retire-vs-dispatch race audit (ISSUE 5): a job can
                    // never be dispatched into the inbox of a worker
                    // that concurrently retires, because both sides run
                    // under this one mutex and each re-validates under
                    // it. `dispatch` checks `retired[i]` before every
                    // placement, so a retired inbox never receives a
                    // job; and retirement requires **every** inbox —
                    // this worker's included — to be empty, so a job
                    // placed before the wait timed out blocks the
                    // retire (the all-empty check below fails) and the
                    // worker loops around to pop it instead. A retired
                    // slot is therefore provably empty, which is what
                    // lets `start_worker` reuse it unconditionally.
                    // `pool_survives_grow_shrink_churn_under_load`
                    // hammers this edge.
                    if timeout.timed_out()
                        && !st.closed
                        && st.alive > min_workers
                        && st.inboxes.iter().all(|q| q.is_empty())
                    {
                        st.retired[me] = true;
                        st.alive -= 1;
                        let hw = st.high_water[me];
                        drop(st);
                        // Wake the dispatcher (it must re-check
                        // `alive`) and peers.
                        self.space.notify_all();
                        self.work.notify_all();
                        return Fetched::Retired(hw);
                    }
                }
            }
        }
    }

    /// Drain up to `limit` more jobs off worker `me`'s own inbox
    /// without blocking — the batched engines' gather step: the jobs
    /// ride in the same lane batch as the one just fetched by
    /// [`SharedQueue::next`]. Frees inbox slots, so the dispatcher is
    /// woken.
    fn drain_own(&self, me: usize, limit: usize) -> Vec<ClipJob> {
        if limit == 0 {
            return Vec::new();
        }
        let mut st = self.state.lock().unwrap();
        let mut jobs = Vec::new();
        while jobs.len() < limit {
            match st.inboxes[me].pop_front() {
                Some(job) => jobs.push(job),
                None => break,
            }
        }
        drop(st);
        if !jobs.is_empty() {
            self.space.notify_all();
        }
        jobs
    }

    /// Deadline-bounded gather (DESIGN.md §Planner): pull up to
    /// `limit` more jobs whose clip length matches `timesteps` off
    /// worker `me`'s own inbox, waiting up to `hold` for stragglers
    /// while the batch is unfilled. Mismatched clips are left queued
    /// (they anchor a later batch), so one engine call never mixes
    /// clip lengths. Every removal frees an inbox slot and wakes the
    /// dispatcher — crucial here, since the whole point of the hold is
    /// to let more arrivals join the batch.
    fn drain_own_matching(
        &self,
        me: usize,
        timesteps: usize,
        limit: usize,
        hold: Duration,
    ) -> Vec<ClipJob> {
        if limit == 0 {
            return Vec::new();
        }
        let hold_until = Instant::now() + hold; // lint: wall-clock
        let mut st = self.state.lock().unwrap();
        let mut jobs = Vec::new();
        loop {
            let before = jobs.len();
            let mut i = 0;
            while jobs.len() < limit && i < st.inboxes[me].len() {
                if st.inboxes[me][i].frames.len() == timesteps {
                    let job = st.inboxes[me].remove(i).expect("index in range");
                    jobs.push(job);
                } else {
                    i += 1;
                }
            }
            if jobs.len() > before {
                self.space.notify_all();
            }
            if jobs.len() >= limit || st.closed || st.aborted {
                return jobs;
            }
            let now = Instant::now(); // lint: wall-clock
            let left = match hold_until.checked_duration_since(now) {
                Some(left) if !left.is_zero() => left,
                _ => return jobs,
            };
            let (next_st, _timeout) = self.work.wait_timeout(st, left).unwrap();
            st = next_st;
        }
    }

    /// Mark the job stream exhausted and wake every waiting worker.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.work.notify_all();
    }

    /// Flag an engine/factory failure: stop admitting jobs and wake a
    /// dispatcher blocked on a full pool so it can observe the flag.
    pub fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.aborted = true;
        drop(st);
        self.space.notify_all();
    }

    /// Deregister an exiting worker; returns its inbox high-water mark.
    pub fn worker_exit(&self, me: usize) -> usize {
        let mut st = self.state.lock().unwrap();
        st.alive -= 1;
        let hw = st.high_water[me];
        drop(st);
        // Wake the dispatcher (it must re-check `alive`) and peers.
        self.space.notify_all();
        self.work.notify_all();
        hw
    }
}

/// Body of one worker thread: build the engine, serve jobs until the
/// queue closes (or the worker retires under dynamic sizing), and
/// account busy/idle/steal counters. Returns the worker counters plus
/// whatever per-stage counters the engine accumulated
/// ([`Engine::stage_metrics`]), so the pool can aggregate hop/stage
/// telemetry across workers. A non-zero `hold` switches the batch
/// gather to deadline-bounded, length-matched assembly.
fn worker_loop<E, F>(
    me: usize,
    queue: &SharedQueue,
    factory: &F,
    results: Sender<WorkerResult<E::Output>>,
    steal: StealPolicy,
    shrink: Option<(Duration, usize)>,
    hold: Duration,
) -> (WorkerMetrics, Vec<StageMetrics>)
where
    E: Engine,
    F: Fn(usize) -> Result<E>,
{
    /// Deregister on unwind too: if `Engine::infer` panics and the
    /// worker silently leaks its `alive` registration, a dispatcher
    /// blocked on a full pool waits on `space` forever instead of the
    /// panic propagating through `join` in [`run_pool`].
    struct ExitGuard<'a> {
        queue: &'a SharedQueue,
        me: usize,
        armed: bool,
    }
    impl Drop for ExitGuard<'_> {
        fn drop(&mut self) {
            if self.armed {
                self.queue.worker_exit(self.me);
            }
        }
    }

    let mut wm = WorkerMetrics::new(me);
    let mut guard = ExitGuard {
        queue,
        me,
        armed: true,
    };
    let mut engine = match factory(me) {
        Ok(e) => e,
        Err(e) => {
            queue.abort();
            let _ = results.send(Err(e));
            guard.armed = false;
            wm.inbox_high_water = queue.worker_exit(me);
            return (wm, Vec::new());
        }
    };
    'serve: loop {
        let wait0 = Instant::now(); // lint: wall-clock
        let (job, stolen) = match queue.next(me, steal, shrink) {
            Fetched::Job(job, stolen) => (job, stolen),
            Fetched::Closed => {
                wm.idle += wait0.elapsed(); // final wait-for-close counts too
                break;
            }
            Fetched::Retired(high_water) => {
                // `next` already deregistered this worker; skip the
                // drop-guard's `worker_exit`.
                wm.idle += wait0.elapsed();
                wm.retired = true;
                wm.inbox_high_water = high_water;
                wm.failovers = engine.failovers();
                guard.armed = false;
                return (wm, engine.stage_metrics());
            }
        };
        wm.idle += wait0.elapsed();
        if stolen {
            wm.stolen += 1;
        }
        // A batch-capable engine drains its own inbox behind the
        // fetched job (up to one lane batch), so the queued backlog is
        // swept through the CIM rows in one call; per-clip engines
        // (`max_batch` == 1) skip the drain and take the old path.
        let cap = engine.max_batch().max(1);
        let mut jobs = vec![job];
        if cap > 1 {
            if hold.is_zero() {
                jobs.extend(queue.drain_own(me, cap - 1));
            } else {
                let timesteps = jobs[0].frames.len();
                jobs.extend(queue.drain_own_matching(me, timesteps, cap - 1, hold));
            }
        }
        let clips: Vec<&[SpikePlane]> = jobs.iter().map(|j| j.frames.as_slice()).collect();
        // Engine-internal instrumentation (pipeline stages, hops)
        // attributes to the batch anchor's trace; the per-clip `infer`
        // spans below cover every batch member. A disabled tracer
        // takes no timestamp here (`should_sample` is one relaxed
        // load).
        let _tscope = trace::bind(jobs[0].trace);
        let tr = trace::tracer();
        let infer0 = jobs
            .iter()
            .any(|j| tr.should_sample(j.trace))
            .then(|| tr.now_us());
        let busy0 = Instant::now(); // lint: wall-clock
        let outcome = engine.infer_batch(&clips);
        wm.busy += busy0.elapsed();
        if let Some(s0) = infer0 {
            let end = tr.now_us();
            for j in &jobs {
                tr.record_span(j.trace, "infer", s0, end);
            }
        }
        match outcome {
            Ok(outputs) => {
                if outputs.len() != jobs.len() {
                    queue.abort();
                    let _ = results.send(Err(Error::Runtime(format!(
                        "engine returned {} outputs for a {}-clip batch",
                        outputs.len(),
                        jobs.len()
                    ))));
                    break;
                }
                for (job, output) in jobs.into_iter().zip(outputs) {
                    wm.clips += 1;
                    let latency = job.t0.elapsed();
                    super::server::observe_clip_done(job.trace, latency);
                    let done = CompletedClip {
                        seq: job.seq,
                        output,
                        latency,
                        frames: job.frames.len() as u64,
                        worker: me,
                    };
                    if results.send(Ok(done)).is_err() {
                        break 'serve;
                    }
                }
            }
            Err(e) => {
                queue.abort();
                let _ = results.send(Err(e));
                break;
            }
        }
    }
    guard.armed = false;
    wm.inbox_high_water = queue.worker_exit(me);
    wm.failovers = engine.failovers();
    (wm, engine.stage_metrics())
}

/// Drain a stream of [`ClipJob`]s through a pool of engine workers.
///
/// `factory` builds one engine per worker **inside that worker's
/// thread** (so engines — like PJRT handles — never need to be
/// `Send`); it must be `Sync` because every worker borrows it. The
/// call returns once the job sender is dropped and every in-flight
/// clip has been emitted.
///
/// Responses are reordered into sequence order by the emission stage
/// before being returned. The first engine or factory error fails
/// fast: dispatch stops admitting jobs, at most the clips already
/// resident in the pool complete, and the run returns that error; a
/// dead worker's queued clips are re-acquired by its peers under
/// [`StealPolicy::Steal`]. A panicking engine propagates its panic
/// out of `run_pool` (worker registration is unwound by a drop
/// guard, so the dispatcher cannot hang on a full pool).
///
/// With [`PoolConfig::sizing`] set, the pool starts at `min_workers`
/// and breathes with the load: the dispatcher starts another worker
/// (up to `max_workers`, reusing slots freed by retirement) whenever
/// every inbox is full, and a worker that has idled `shrink_idle`
/// over a drained queue retires down to `min_workers`.
/// [`PoolRun::workers`] reports one entry per worker thread ever
/// started, retirees included.
pub fn run_pool<E, F>(
    cfg: &PoolConfig,
    jobs: Receiver<ClipJob>,
    factory: &F,
) -> Result<PoolRun<E::Output>>
where
    E: Engine,
    F: Fn(usize) -> Result<E> + Sync,
{
    let depth = cfg.inbox_depth.max(1);
    let steal = cfg.steal;
    let hold = Duration::from_micros(u64::from(cfg.deadline_us));
    // Fixed pools start all workers up front and never grow or shrink
    // (a grow limit of 0 disables growth; no shrink timeout).
    let (initial, grow_limit, shrink) = match cfg.sizing {
        None => (cfg.workers.max(1), 0, None),
        Some(s) => {
            let min = s.min_workers.max(1);
            let max = s.max_workers.max(min);
            (min, max, Some((s.shrink_idle, min)))
        }
    };
    let queue = SharedQueue::new();
    let (rtx, rrx) = channel::<WorkerResult<E::Output>>();

    crate::sync::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(initial);
        for _ in 0..initial {
            let wi = queue.start_worker();
            let queue = &queue;
            let rtx = rtx.clone();
            handles.push(scope.spawn(move || {
                worker_loop::<E, F>(wi, queue, factory, rtx, steal, shrink, hold)
            }));
        }

        // Emission stage: sequence-number reorder buffer. Clips arrive
        // in completion order; they leave in arrival order.
        let emission = scope.spawn(move || {
            let mut pending: BTreeMap<u64, CompletedClip<E::Output>> = BTreeMap::new();
            let mut next_seq = 0u64;
            let mut ready: Vec<CompletedClip<E::Output>> = Vec::new();
            let mut first_err: Option<Error> = None;
            for msg in rrx.iter() {
                match msg {
                    Ok(done) => {
                        pending.insert(done.seq, done);
                        while let Some(d) = pending.remove(&next_seq) {
                            ready.push(d);
                            next_seq += 1;
                        }
                    }
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
            }
            // After an error some sequence numbers never complete;
            // flush the stragglers in order so output stays sorted.
            for d in pending.into_values() {
                ready.push(d);
            }
            (ready, first_err)
        });

        // Dispatch stage (the calling thread): bounded inboxes make
        // `dispatch` block when the pool saturates, which leaves jobs
        // unread in `jobs`, which blocks the bounded ingest channel —
        // backpressure reaches the event source without drops. Under
        // dynamic sizing, saturation first grows the pool; only a
        // full pool at `max_workers` blocks.
        'dispatch: for job in jobs.iter() {
            let mut job = job;
            // Covers placement, including any grow and the blocked
            // wait on a saturated pool (inert unless sampled).
            let _dspan = trace::tracer().span(job.trace, "dispatch");
            loop {
                match queue.dispatch(depth, job, grow_limit) {
                    Dispatch::Placed => continue 'dispatch,
                    Dispatch::Closed => break 'dispatch,
                    Dispatch::Grow(returned) => {
                        job = returned;
                        let wi = queue.start_worker();
                        let queue = &queue;
                        let rtx = rtx.clone();
                        handles.push(scope.spawn(move || {
                            worker_loop::<E, F>(wi, queue, factory, rtx, steal, shrink, hold)
                        }));
                    }
                }
            }
        }
        queue.close();
        // The emission stage owns the only other receiver-facing end;
        // drop our sender so it terminates when the workers do.
        drop(rtx);

        let mut wm = Vec::with_capacity(handles.len());
        let mut stages: Vec<StageMetrics> = Vec::new();
        for h in handles {
            let (w, ws) = h.join().expect("pool worker panicked");
            wm.push(w);
            for (i, s) in ws.into_iter().enumerate() {
                if stages.len() <= i {
                    stages.push(StageMetrics::new(i, s.layers));
                }
                stages[i].absorb(&s);
            }
        }
        let (clips, first_err) = emission.join().expect("emission stage panicked");
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(PoolRun {
            clips,
            workers: wm,
            stages,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use crate::sync::mpsc::sync_channel;
    use crate::sync::Arc;

    /// Deterministic engine: output = total spikes in the clip.
    struct CountEngine;

    impl Engine for CountEngine {
        type Output = u64;

        fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
            Ok(clip.iter().map(|p| p.count_spikes()).sum())
        }
    }

    /// Engine whose service time varies with clip content, so
    /// completion order scrambles under a multi-worker pool.
    struct SkewEngine;

    impl Engine for SkewEngine {
        type Output = u64;

        fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
            let n: u64 = clip.iter().map(|p| p.count_spikes()).sum();
            // later-arriving small clips finish before earlier big ones
            std::thread::sleep(Duration::from_millis((n % 5) * 3));
            Ok(n)
        }
    }

    fn job(seq: u64, spikes: usize) -> ClipJob {
        let mut p = SpikePlane::zeros(1, 8, 8);
        for i in 0..spikes.min(p.len()) {
            p.as_mut_slice()[i] = 1;
        }
        ClipJob {
            seq,
            t0: Instant::now(),
            trace: TraceId::NONE,
            frames: vec![p],
        }
    }

    /// Pre-fill an unbounded channel with `n` jobs of varying size.
    fn job_stream(n: u64) -> Receiver<ClipJob> {
        let (tx, rx) = channel();
        for seq in 0..n {
            tx.send(job(seq, (seq as usize * 7 + 3) % 23)).unwrap();
        }
        rx
    }

    #[test]
    fn responses_reordered_into_arrival_order() {
        let cfg = PoolConfig {
            workers: 4,
            inbox_depth: 2,
            steal: StealPolicy::Steal,
            ..PoolConfig::default()
        };
        let run = run_pool(&cfg, job_stream(24), &|_| Ok(SkewEngine)).unwrap();
        assert_eq!(run.clips.len(), 24);
        for (i, c) in run.clips.iter().enumerate() {
            assert_eq!(c.seq, i as u64, "reorder buffer must restore order");
            assert_eq!(c.output, ((i as u64 * 7 + 3) % 23).min(64));
        }
        let served: u64 = run.workers.iter().map(|w| w.clips).sum();
        assert_eq!(served, 24);
    }

    #[test]
    fn pinned_pool_still_serves_everything_in_order() {
        let cfg = PoolConfig {
            workers: 3,
            inbox_depth: 1,
            steal: StealPolicy::Pinned,
            ..PoolConfig::default()
        };
        let run = run_pool(&cfg, job_stream(17), &|_| Ok(CountEngine)).unwrap();
        assert_eq!(run.clips.len(), 17);
        assert!(run.clips.windows(2).all(|w| w[0].seq < w[1].seq));
        assert!(run.workers.iter().all(|w| w.stolen == 0));
    }

    /// Satellite (b): a saturated pool throttles ingestion instead of
    /// dropping clips. With every engine gated shut, the number of
    /// jobs the producer manages to hand over can never exceed the
    /// pool capacity plus the one job the dispatcher holds — an
    /// invariant that holds at *every* instant, so sampling it while
    /// the gate is closed is deterministic. Once the gate opens, all
    /// clips must complete.
    #[test]
    fn saturated_pool_throttles_ingestion_without_drops() {
        const TOTAL: u64 = 32;
        let cfg = PoolConfig {
            workers: 2,
            inbox_depth: 1,
            steal: StealPolicy::Steal,
            ..PoolConfig::default()
        };
        let gate = Arc::new(AtomicBool::new(false));
        let sent = Arc::new(AtomicUsize::new(0));
        let sent_at_release = Arc::new(AtomicUsize::new(usize::MAX));

        // Rendezvous job channel: a send completes only when the
        // dispatcher takes the job, so `sent` counts admitted jobs.
        let (tx, rx) = sync_channel::<ClipJob>(0);
        let producer = {
            let sent = Arc::clone(&sent);
            crate::sync::thread::spawn(move || {
                for seq in 0..TOTAL {
                    if tx.send(job(seq, 4)).is_err() {
                        return;
                    }
                    sent.fetch_add(1, Ordering::SeqCst);
                }
            })
        };
        let releaser = {
            let gate = Arc::clone(&gate);
            let sent = Arc::clone(&sent);
            let sent_at_release = Arc::clone(&sent_at_release);
            crate::sync::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(60));
                sent_at_release.store(sent.load(Ordering::SeqCst), Ordering::SeqCst);
                gate.store(true, Ordering::SeqCst);
            })
        };

        struct GatedEngine(Arc<AtomicBool>);
        impl Engine for GatedEngine {
            type Output = u64;
            fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
                while !self.0.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(clip.iter().map(|p| p.count_spikes()).sum())
            }
        }

        let gate_f = Arc::clone(&gate);
        let run = run_pool(&cfg, rx, &move |_| Ok(GatedEngine(Arc::clone(&gate_f))))
            .unwrap();
        producer.join().unwrap();
        releaser.join().unwrap();

        // capacity = workers * (inbox_depth + 1) = 4, plus the one job
        // the dispatcher may hold while blocked on a full pool.
        let bound = cfg.capacity() + 1;
        let admitted = sent_at_release.load(Ordering::SeqCst);
        assert!(
            admitted <= bound,
            "saturated pool admitted {admitted} > bound {bound}"
        );
        // Nothing was dropped: every clip completed after release.
        assert_eq!(run.clips.len(), TOTAL as usize);
        assert_eq!(sent.load(Ordering::SeqCst), TOTAL as usize);
        assert!(run.clips.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    /// Satellite: dynamic sizing. A bursty load against gated engines
    /// forces the dispatcher to grow the pool to `max_workers` (with
    /// every engine blocked, placing the 5th job is impossible at two
    /// workers × depth 1 — growth is the only way the burst fits), and
    /// a drained queue shrinks it back toward `min_workers` before the
    /// final trickle job arrives.
    #[test]
    fn pool_grows_under_burst_and_shrinks_when_drained() {
        let cfg = PoolConfig {
            inbox_depth: 1,
            steal: StealPolicy::Steal,
            sizing: Some(PoolSizing {
                min_workers: 1,
                max_workers: 3,
                shrink_idle: Duration::from_millis(25),
            }),
            ..PoolConfig::default()
        };

        struct GatedEngine(Arc<AtomicBool>);
        impl Engine for GatedEngine {
            type Output = u64;
            fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
                while !self.0.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(clip.iter().map(|p| p.count_spikes()).sum())
            }
        }

        let gate = Arc::new(AtomicBool::new(false));
        // Rendezvous job channel: a send completes only when the
        // dispatcher takes the job, so the whole burst being admitted
        // while the gate is closed proves the pool grew.
        let (tx, rx) = sync_channel::<ClipJob>(0);
        let producer = {
            let gate = Arc::clone(&gate);
            crate::sync::thread::spawn(move || {
                // Phase 1: a 6-job burst nobody can serve yet. At max
                // capacity (3 workers × (1 inbox + 1 in-flight)) it
                // fits exactly — but only after two growth steps.
                for seq in 0..6 {
                    tx.send(job(seq, 4)).unwrap();
                }
                gate.store(true, Ordering::SeqCst);
                // Phase 2: the queue drains, then idles far beyond
                // shrink_idle; surplus workers retire down to min.
                std::thread::sleep(Duration::from_millis(400));
                tx.send(job(6, 4)).unwrap();
            })
        };

        let gate_f = Arc::clone(&gate);
        let run = run_pool(&cfg, rx, &move |_| Ok(GatedEngine(Arc::clone(&gate_f)))).unwrap();
        producer.join().unwrap();

        assert_eq!(run.clips.len(), 7);
        assert!(run.clips.windows(2).all(|w| w[0].seq < w[1].seq));
        // the burst grew the pool from min (1) to max (3)
        assert_eq!(run.workers.len(), 3, "{:?}", run.workers);
        // the drained queue retired surplus workers, never below min
        let retired = run.workers.iter().filter(|w| w.retired).count();
        assert!(
            (1..=2).contains(&retired),
            "want 1–2 retirees, got {:?}",
            run.workers
        );
        // nothing was lost across the resize
        let served: u64 = run.workers.iter().map(|w| w.clips).sum();
        assert_eq!(served, 7);
    }

    /// Satellite (ISSUE 5): grow/shrink churn under load. An
    /// aggressive shrink timeout (1 ms) against a bursty, stuttering
    /// job stream forces the pool through many grow and retire cycles
    /// — the dispatch-scan-vs-retire window — while clips keep
    /// flowing. The retire invariant (a retiring worker's inbox is
    /// provably empty, see `SharedQueue::next`) means no clip can ever
    /// be lost in a retired inbox or served twice off a reused slot:
    /// every sequence number must come back exactly once, in order.
    #[test]
    fn pool_survives_grow_shrink_churn_under_load() {
        const TOTAL: u64 = 200;
        let cfg = PoolConfig {
            inbox_depth: 1,
            steal: StealPolicy::Steal,
            sizing: Some(PoolSizing {
                min_workers: 1,
                max_workers: 4,
                shrink_idle: Duration::from_millis(1),
            }),
            ..PoolConfig::default()
        };

        /// Every 7th clip is slow, so inboxes back up (grow pressure)
        /// and then drain while the producer stutters (shrink
        /// pressure).
        struct ChurnEngine;
        impl Engine for ChurnEngine {
            type Output = u64;
            fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
                let n: u64 = clip.iter().map(|p| p.count_spikes()).sum();
                if n % 7 == 0 {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Ok(n)
            }
        }

        // Rendezvous channel + stuttering producer: bursts of 8 jobs
        // back-to-back (forcing growth past one worker × depth 1),
        // then a pause well past shrink_idle (forcing retirement).
        let (tx, rx) = sync_channel::<ClipJob>(0);
        let producer = crate::sync::thread::spawn(move || {
            for seq in 0..TOTAL {
                if tx.send(job(seq, (seq as usize * 3 + 1) % 23)).is_err() {
                    return;
                }
                if seq % 8 == 7 {
                    std::thread::sleep(Duration::from_millis(6));
                }
            }
        });

        let run = run_pool(&cfg, rx, &|_| Ok(ChurnEngine)).unwrap();
        producer.join().unwrap();

        // No clip lost, duplicated, or reordered across any resize.
        assert_eq!(run.clips.len(), TOTAL as usize);
        for (i, c) in run.clips.iter().enumerate() {
            assert_eq!(c.seq, i as u64, "clip {i} lost or reordered under churn");
        }
        let served: u64 = run.workers.iter().map(|w| w.clips).sum();
        assert_eq!(served, TOTAL, "every clip served exactly once");
        // The churn actually happened: the pool both grew past min and
        // retired workers along the way.
        assert!(
            run.workers.len() > 1,
            "stream never grew the pool: {:?}",
            run.workers
        );
        assert!(
            run.workers.iter().any(|w| w.retired),
            "stream never shrank the pool: {:?}",
            run.workers
        );
    }

    /// Satellite: a batch-capable engine drains its own inbox behind
    /// every fetched job. With the single worker gated shut while the
    /// dispatcher fills its inbox, the backlog must come back in at
    /// least one multi-clip batch — every clip exactly once, in order,
    /// never more than `max_batch` per call.
    #[test]
    fn batched_engine_drains_inbox_in_batches() {
        let cfg = PoolConfig {
            workers: 1,
            inbox_depth: 4,
            steal: StealPolicy::Steal,
            ..PoolConfig::default()
        };

        struct BatchProbe {
            gate: Arc<AtomicBool>,
            sizes: Arc<Mutex<Vec<usize>>>,
        }
        impl Engine for BatchProbe {
            type Output = u64;
            fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
                Ok(clip.iter().map(|p| p.count_spikes()).sum())
            }
            fn max_batch(&self) -> usize {
                8
            }
            fn infer_batch(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<u64>> {
                while !self.gate.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                self.sizes.lock().unwrap().push(clips.len());
                clips.iter().map(|c| self.infer(c)).collect()
            }
        }

        let gate = Arc::new(AtomicBool::new(false));
        let sizes = Arc::new(Mutex::new(Vec::new()));
        // Rendezvous channel: send 6 jobs while the engine is gated —
        // the first blocks the worker mid-batch, the rest pile into
        // its inbox — then open the gate.
        let (tx, rx) = sync_channel::<ClipJob>(0);
        let producer = {
            let gate = Arc::clone(&gate);
            crate::sync::thread::spawn(move || {
                for seq in 0..6 {
                    tx.send(job(seq, (seq as usize * 5 + 2) % 23)).unwrap();
                }
                gate.store(true, Ordering::SeqCst);
            })
        };

        let gate_f = Arc::clone(&gate);
        let sizes_f = Arc::clone(&sizes);
        let run = run_pool(&cfg, rx, &move |_| {
            Ok(BatchProbe {
                gate: Arc::clone(&gate_f),
                sizes: Arc::clone(&sizes_f),
            })
        })
        .unwrap();
        producer.join().unwrap();

        assert_eq!(run.clips.len(), 6);
        for (i, c) in run.clips.iter().enumerate() {
            assert_eq!(c.seq, i as u64);
            assert_eq!(c.output, ((i as u64 * 5 + 2) % 23).min(64));
        }
        let sizes = sizes.lock().unwrap();
        assert_eq!(sizes.iter().sum::<usize>(), 6);
        assert!(sizes.iter().all(|&s| s <= 8), "{sizes:?}");
        assert!(
            sizes.iter().any(|&s| s >= 2),
            "gated backlog never batched: {sizes:?}"
        );
    }

    /// Satellite (d), pool twin of the server's deadline assembly:
    /// with `deadline_us` set, a batch-capable worker holds its
    /// filling batch for same-length stragglers and never mixes clip
    /// lengths in one engine call; mismatched clips anchor later
    /// batches and nothing is lost or reordered. Also exercises the
    /// stage-counter surfacing satellite: the worker engine's
    /// [`Engine::stage_metrics`] aggregate into [`PoolRun::stages`].
    #[test]
    fn pool_deadline_assembles_length_matched_batches() {
        let cfg = PoolConfig {
            workers: 1,
            inbox_depth: 8,
            steal: StealPolicy::Steal,
            deadline_us: 20_000,
            ..PoolConfig::default()
        };

        struct LenProbe {
            batches: Arc<Mutex<Vec<Vec<usize>>>>,
            steps: u64,
        }
        impl Engine for LenProbe {
            type Output = u64;
            fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
                Ok(clip.iter().map(|p| p.count_spikes()).sum())
            }
            fn max_batch(&self) -> usize {
                8
            }
            fn infer_batch(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<u64>> {
                let lens: Vec<usize> = clips.iter().map(|c| c.len()).collect();
                self.steps += lens.iter().map(|&l| l as u64).sum::<u64>();
                self.batches.lock().unwrap().push(lens);
                clips.iter().map(|c| self.infer(c)).collect()
            }
            fn stage_metrics(&self) -> Vec<StageMetrics> {
                let mut s = StageMetrics::new(0, (0, 1));
                s.steps = self.steps;
                vec![s]
            }
        }

        fn tjob(seq: u64, timesteps: usize) -> ClipJob {
            ClipJob {
                seq,
                t0: Instant::now(),
                trace: TraceId::NONE,
                frames: vec![SpikePlane::zeros(1, 4, 4); timesteps],
            }
        }

        // Rendezvous channel: mixed 1- and 2-frame clips, interleaved.
        let (tx, rx) = sync_channel::<ClipJob>(0);
        let producer = crate::sync::thread::spawn(move || {
            for (seq, t) in [1usize, 2, 1, 2, 1, 1].into_iter().enumerate() {
                tx.send(tjob(seq as u64, t)).unwrap();
            }
        });

        let batches = Arc::new(Mutex::new(Vec::new()));
        let batches_f = Arc::clone(&batches);
        let run = run_pool(&cfg, rx, &move |_| {
            Ok(LenProbe {
                batches: Arc::clone(&batches_f),
                steps: 0,
            })
        })
        .unwrap();
        producer.join().unwrap();

        assert_eq!(run.clips.len(), 6);
        assert!(run.clips.windows(2).all(|w| w[0].seq < w[1].seq));
        let batches = batches.lock().unwrap();
        for b in batches.iter() {
            assert!(
                b.windows(2).all(|w| w[0] == w[1]),
                "mixed-length batch {b:?}"
            );
        }
        assert_eq!(batches.iter().map(|b| b.len()).sum::<usize>(), 6);
        // the hold actually assembled multi-clip batches out of
        // same-length stragglers that trickled in behind the anchor
        assert!(batches.iter().any(|b| b.len() >= 2), "{batches:?}");
        // worker stage counters surfaced and aggregated: steps counts
        // every frame served (4 one-frame + 2 two-frame clips)
        assert_eq!(run.stages.len(), 1);
        assert_eq!(run.stages[0].steps, 8);
    }

    /// Without a sizing policy the pool is exactly as static as
    /// before: all workers start up front, none retire.
    #[test]
    fn fixed_pool_never_resizes() {
        let cfg = PoolConfig {
            workers: 3,
            inbox_depth: 1,
            steal: StealPolicy::Steal,
            ..PoolConfig::default()
        };
        let run = run_pool(&cfg, job_stream(9), &|_| Ok(CountEngine)).unwrap();
        assert_eq!(run.workers.len(), 3);
        assert!(run.workers.iter().all(|w| !w.retired));
    }

    #[test]
    fn stealing_moves_work_off_a_slow_worker() {
        struct PerWorker {
            slow: bool,
        }
        impl Engine for PerWorker {
            type Output = u64;
            fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
                if self.slow {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Ok(clip.iter().map(|p| p.count_spikes()).sum())
            }
        }
        let cfg = PoolConfig {
            workers: 2,
            inbox_depth: 2,
            steal: StealPolicy::Steal,
            ..PoolConfig::default()
        };
        let run = run_pool(&cfg, job_stream(12), &|wi| Ok(PerWorker { slow: wi == 0 }))
            .unwrap();
        assert_eq!(run.clips.len(), 12);
        // the fast worker must end up serving at least as many clips
        // as the one sleeping 20 ms per clip
        assert!(run.workers[1].clips >= run.workers[0].clips);
        assert_eq!(run.workers[0].clips + run.workers[1].clips, 12);
    }

    #[test]
    fn engine_error_propagates_and_fails_fast() {
        use crate::sync::atomic::{AtomicU64, Ordering as AOrd};
        // Every infer errors; count how many the pool attempted.
        static TRIED: AtomicU64 = AtomicU64::new(0);
        struct Bad;
        impl Engine for Bad {
            type Output = ();
            fn infer(&mut self, _: &[SpikePlane]) -> Result<()> {
                TRIED.fetch_add(1, AOrd::SeqCst);
                Err(Error::Runtime("boom".into()))
            }
        }
        let cfg = PoolConfig::with_workers(2);
        assert!(run_pool(&cfg, job_stream(64), &|_| Ok(Bad)).is_err());
        // Fail fast: dispatch stops on the first error, so at most the
        // clips resident in the pool (plus one per worker already
        // in-flight) were ever attempted — nowhere near all 64.
        assert!(TRIED.load(AOrd::SeqCst) <= (cfg.capacity() + 1) as u64);
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn worker_panic_propagates_instead_of_hanging() {
        struct Panicker;
        impl Engine for Panicker {
            type Output = ();
            fn infer(&mut self, _: &[SpikePlane]) -> Result<()> {
                panic!("engine exploded")
            }
        }
        // One worker + a deep job stream: without the exit guard the
        // dispatcher would block forever on a full pool.
        let cfg = PoolConfig {
            workers: 1,
            inbox_depth: 1,
            steal: StealPolicy::Steal,
            ..PoolConfig::default()
        };
        let _ = run_pool(&cfg, job_stream(16), &|_| Ok(Panicker));
    }

    #[test]
    fn factory_error_propagates() {
        let cfg = PoolConfig::with_workers(2);
        let r = run_pool::<CountEngine, _>(&cfg, job_stream(3), &|wi| {
            if wi == 0 {
                Err(Error::Runtime("no engine".into()))
            } else {
                Ok(CountEngine)
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn high_water_marks_respect_inbox_depth() {
        let cfg = PoolConfig {
            workers: 2,
            inbox_depth: 3,
            steal: StealPolicy::Steal,
            ..PoolConfig::default()
        };
        let run = run_pool(&cfg, job_stream(40), &|_| Ok(CountEngine)).unwrap();
        for w in &run.workers {
            assert!(w.inbox_high_water <= 3, "{w:?}");
        }
    }

    #[test]
    fn empty_job_stream() {
        let (tx, rx) = channel::<ClipJob>();
        drop(tx);
        let run = run_pool(&PoolConfig::default(), rx, &|_| Ok(CountEngine)).unwrap();
        assert!(run.clips.is_empty());
        assert_eq!(run.workers.len(), 4);
    }
}
