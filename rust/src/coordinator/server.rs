//! Streaming inference server: the L3 request path.
//!
//! Three stages connected by bounded rendezvous channels — the
//! system-level analogue of the chip's asynchronous handshaking:
//! ingestion (event binning) → inference (simulated core or PJRT
//! golden model) → emission. Backpressure propagates through the
//! bounded channels; a slow inference stage throttles ingestion
//! instead of dropping events.

use std::sync::mpsc::{sync_channel, Receiver};
use std::time::{Duration, Instant};

use crate::dvs::binning::bin_events;
use crate::dvs::event::Event;
use crate::error::{Error, Result};
use crate::snn::spikes::SpikePlane;

use super::metrics::Metrics;

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Frame height.
    pub height: usize,
    /// Frame width.
    pub width: usize,
    /// Timesteps per clip.
    pub timesteps: usize,
    /// Microseconds per timestep bin.
    pub bin_us: u32,
    /// Bounded queue depth between stages (backpressure window).
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            height: 64,
            width: 64,
            timesteps: 10,
            bin_us: 1000,
            queue_depth: 2,
        }
    }
}

/// An inference engine pluggable into the server.
pub trait Engine {
    /// Engine output per clip.
    type Output: Send + 'static;

    /// Run one clip (frames indexed by timestep).
    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Self::Output>;
}

/// A completed request.
#[derive(Debug)]
pub struct Response<O> {
    /// Request id (arrival order).
    pub id: u64,
    /// Engine output.
    pub output: O,
    /// End-to-end latency (ingestion start → inference done).
    pub latency: Duration,
}

/// The streaming server.
pub struct InferenceServer {
    /// Configuration.
    pub cfg: ServerConfig,
}

impl InferenceServer {
    /// New server.
    pub fn new(cfg: ServerConfig) -> Self {
        InferenceServer { cfg }
    }

    /// Serve a stream of event bursts (one `Vec<Event>` per request)
    /// through a pipelined ingest → infer flow. The ingestion stage
    /// runs on its own thread; inference runs on the calling thread
    /// (PJRT handles are not `Send`), overlapping binning of request
    /// `n+1` with inference of request `n`.
    ///
    /// Returns responses in arrival order plus aggregate metrics.
    pub fn serve<E: Engine>(
        &self,
        requests: Vec<Vec<Event>>,
        engine: &mut E,
    ) -> Result<(Vec<Response<E::Output>>, Metrics)> {
        let cfg = self.cfg;
        let (tx, rx): (_, Receiver<(u64, Instant, Vec<SpikePlane>)>) =
            sync_channel(cfg.queue_depth);

        let ingest = std::thread::spawn(move || {
            for (id, events) in requests.into_iter().enumerate() {
                let t0 = Instant::now();
                let frames = bin_events(
                    &events,
                    cfg.height,
                    cfg.width,
                    cfg.timesteps,
                    cfg.bin_us,
                );
                if tx.send((id as u64, t0, frames)).is_err() {
                    return; // consumer dropped
                }
            }
        });

        let mut responses = Vec::new();
        let mut metrics = Metrics::new();
        for (id, t0, frames) in rx.iter() {
            let output = engine.infer(&frames)?;
            let latency = t0.elapsed();
            metrics.record_clip(latency, frames.len() as u64);
            responses.push(Response {
                id,
                output,
                latency,
            });
        }
        ingest
            .join()
            .map_err(|_| Error::Runtime("ingest thread panicked".into()))?;
        Ok((responses, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvs::event::Polarity;

    struct CountEngine;

    impl Engine for CountEngine {
        type Output = u64;

        fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
            Ok(clip.iter().map(|p| p.count_spikes()).sum())
        }
    }

    fn burst(n: usize) -> Vec<Event> {
        (0..n)
            .map(|i| Event {
                y: (i % 8) as u16,
                x: (i / 8 % 8) as u16,
                polarity: Polarity::On,
                t_us: (i % 4) as u32 * 1000,
            })
            .collect()
    }

    fn small_cfg() -> ServerConfig {
        ServerConfig {
            height: 8,
            width: 8,
            timesteps: 4,
            bin_us: 1000,
            queue_depth: 2,
        }
    }

    #[test]
    fn serves_in_order_with_metrics() {
        let server = InferenceServer::new(small_cfg());
        let reqs = vec![burst(10), burst(20), burst(5)];
        let (resp, metrics) = server.serve(reqs, &mut CountEngine).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0].id, 0);
        assert_eq!(resp[2].id, 2);
        assert_eq!(metrics.clips, 3);
        assert_eq!(metrics.frames, 12);
        // duplicate-collapsed spike counts are positive
        assert!(resp.iter().all(|r| r.output > 0));
    }

    #[test]
    fn failing_engine_propagates_error() {
        struct Bad;
        impl Engine for Bad {
            type Output = ();
            fn infer(&mut self, _: &[SpikePlane]) -> Result<()> {
                Err(Error::Runtime("boom".into()))
            }
        }
        let server = InferenceServer::new(small_cfg());
        assert!(server.serve(vec![burst(3)], &mut Bad).is_err());
    }

    #[test]
    fn empty_request_list() {
        let server = InferenceServer::new(small_cfg());
        let (resp, metrics) = server.serve(vec![], &mut CountEngine).unwrap();
        assert!(resp.is_empty());
        assert_eq!(metrics.clips, 0);
    }
}
