//! Streaming inference server: the L3 request path.
//!
//! Three stages connected by bounded rendezvous channels — the
//! system-level analogue of the chip's asynchronous handshaking:
//! ingestion (event binning) → inference (simulated core or PJRT
//! golden model) → emission. Backpressure propagates through the
//! bounded channels; a slow inference stage throttles ingestion
//! instead of dropping events.
//!
//! Two inference stages are available: [`InferenceServer::serve`]
//! runs one engine on the calling thread (PJRT handles are not
//! `Send`), and [`InferenceServer::serve_pool`] shards clips across a
//! load-balanced worker pool ([`super::pool`]) while preserving
//! response order (DESIGN.md §Serve).

use std::sync::mpsc::{sync_channel, Receiver};
use std::time::{Duration, Instant};

use crate::dvs::binning::bin_events;
use crate::dvs::event::Event;
use crate::error::{Error, Result};
use crate::net::coordinator::DistributedConfig;
use crate::snn::network::{Network, NetworkState};
use crate::snn::spikes::SpikePlane;

use super::batch::BatchConfig;
use super::metrics::Metrics;
use super::pipeline::PipelineConfig;
use super::pool::{run_pool, ClipJob, PoolConfig};

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Frame height.
    pub height: usize,
    /// Frame width.
    pub width: usize,
    /// Timesteps per clip.
    pub timesteps: usize,
    /// Microseconds per timestep bin.
    pub bin_us: u32,
    /// Bounded queue depth between stages (backpressure window).
    pub queue_depth: usize,
    /// Select the timestep-pipelined functional engine (`Some`) over
    /// the sequential reference (`None`) when engines are built from
    /// this config (`FunctionalEngine::from_config`).
    pub pipeline: Option<PipelineConfig>,
    /// Select the distributed shard engine (`Some`) — layer groups on
    /// self-hosted shard threads behind the wire protocol (`net`,
    /// DESIGN.md §Distributed) — when engines are built from this
    /// config. Mutually exclusive with `pipeline`.
    pub distributed: Option<DistributedConfig>,
    /// Select the batched bit-plane engine (`Some`) — up to 64 clips
    /// packed into `u64` spike lanes and swept through the CIM rows
    /// once per batch ([`super::batch`], DESIGN.md §Perf) — when
    /// engines are built from this config. The serve loops then drain
    /// their queues in batches of up to [`BatchConfig::capacity`]
    /// clips. Mutually exclusive with `pipeline` and `distributed`.
    pub batch: Option<BatchConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            height: 64,
            width: 64,
            timesteps: 10,
            bin_us: 1000,
            queue_depth: 2,
            pipeline: None,
            distributed: None,
            batch: None,
        }
    }
}

/// An inference engine pluggable into the server.
pub trait Engine {
    /// Engine output per clip.
    type Output: Send + 'static;

    /// Run one clip (frames indexed by timestep).
    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Self::Output>;

    /// Largest clip batch [`Engine::infer_batch`] can exploit in one
    /// call. The serve loops drain up to this many queued clips per
    /// dispatch; `1` (the default) keeps the per-clip path.
    fn max_batch(&self) -> usize {
        1
    }

    /// Run a batch of clips, one output per clip in order. The default
    /// loops [`Engine::infer`]; batch-capable engines (the lane-major
    /// [`super::batch::BatchedEngine`]) override it to amortize
    /// dispatch across the batch.
    fn infer_batch(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<Self::Output>> {
        clips.iter().map(|c| self.infer(c)).collect()
    }
}

/// A completed request.
#[derive(Debug)]
pub struct Response<O> {
    /// Request id (arrival order).
    pub id: u64,
    /// Engine output.
    pub output: O,
    /// End-to-end latency (ingestion start → inference done).
    pub latency: Duration,
}

/// The streaming server.
pub struct InferenceServer {
    /// Configuration.
    pub cfg: ServerConfig,
}

impl InferenceServer {
    /// New server.
    pub fn new(cfg: ServerConfig) -> Self {
        InferenceServer { cfg }
    }

    /// Serve a stream of event bursts (one `Vec<Event>` per request)
    /// through a pipelined ingest → infer flow. The ingestion stage
    /// runs on its own thread; inference runs on the calling thread
    /// (PJRT handles are not `Send`), overlapping binning of request
    /// `n+1` with inference of request `n`.
    ///
    /// Returns responses in arrival order plus aggregate metrics.
    pub fn serve<E: Engine>(
        &self,
        requests: Vec<Vec<Event>>,
        engine: &mut E,
    ) -> Result<(Vec<Response<E::Output>>, Metrics)> {
        let cfg = self.cfg;
        let wall0 = Instant::now();
        let (tx, rx): (_, Receiver<ClipJob>) = sync_channel(cfg.queue_depth);

        let ingest = std::thread::spawn(move || {
            for (seq, events) in requests.into_iter().enumerate() {
                if tx.send(bin_request(cfg, seq as u64, &events)).is_err() {
                    return; // consumer dropped
                }
            }
        });

        let mut responses = Vec::new();
        let mut metrics = Metrics::new();
        // Batch-capable engines (`max_batch` > 1) drain whatever the
        // ingest stage has already binned — up to one lane word's
        // worth of clips — and amortize dispatch across the batch; a
        // per-clip engine degenerates to the old one-at-a-time loop.
        let cap = engine.max_batch().max(1);
        let mut jobs: Vec<ClipJob> = Vec::with_capacity(cap);
        while let Ok(first) = rx.recv() {
            jobs.push(first);
            while jobs.len() < cap {
                match rx.try_recv() {
                    Ok(job) => jobs.push(job),
                    Err(_) => break,
                }
            }
            let clips: Vec<&[SpikePlane]> = jobs.iter().map(|j| j.frames.as_slice()).collect();
            let outputs = engine.infer_batch(&clips)?;
            if outputs.len() != jobs.len() {
                return Err(Error::Runtime(format!(
                    "engine returned {} outputs for a {}-clip batch",
                    outputs.len(),
                    jobs.len()
                )));
            }
            for (job, output) in jobs.drain(..).zip(outputs) {
                let latency = job.t0.elapsed();
                metrics.record_clip(latency, job.frames.len() as u64);
                responses.push(Response {
                    id: job.seq,
                    output,
                    latency,
                });
            }
        }
        ingest
            .join()
            .map_err(|_| Error::Runtime("ingest thread panicked".into()))?;
        metrics.wall = wall0.elapsed();
        Ok((responses, metrics))
    }

    /// Serve a stream of event bursts through the **sharded pool
    /// tier**: ingestion (event binning, own thread) → dispatch into
    /// the pool's bounded per-worker inboxes → N engine workers →
    /// emission through a sequence-number reorder buffer.
    ///
    /// `factory` builds one engine per worker, inside that worker's
    /// thread. Responses come back in arrival order regardless of
    /// per-clip latency skew, and a saturated pool throttles the
    /// ingest channel instead of dropping clips (DESIGN.md §Serve).
    /// [`Metrics::workers`] carries the per-worker counters.
    pub fn serve_pool<E, F>(
        &self,
        requests: Vec<Vec<Event>>,
        pool: &PoolConfig,
        factory: F,
    ) -> Result<(Vec<Response<E::Output>>, Metrics)>
    where
        E: Engine,
        F: Fn(usize) -> Result<E> + Sync,
    {
        let cfg = self.cfg;
        let wall0 = Instant::now();
        std::thread::scope(|scope| {
            let (jtx, jrx) = sync_channel::<ClipJob>(cfg.queue_depth);
            let ingest = scope.spawn(move || {
                for (seq, events) in requests.into_iter().enumerate() {
                    if jtx.send(bin_request(cfg, seq as u64, &events)).is_err() {
                        return; // pool aborted; stop binning
                    }
                }
            });
            let run = run_pool(pool, jrx, &factory);
            ingest
                .join()
                .map_err(|_| Error::Runtime("ingest thread panicked".into()))?;
            let run = run?;
            let mut metrics = Metrics::new();
            let mut responses = Vec::with_capacity(run.clips.len());
            for done in run.clips {
                metrics.record_clip(done.latency, done.frames);
                responses.push(Response {
                    id: done.seq,
                    output: done.output,
                    latency: done.latency,
                });
            }
            metrics.workers = run.workers;
            metrics.wall = wall0.elapsed();
            Ok((responses, metrics))
        })
    }
}

/// Bin one request into a sequenced clip job — the shared ingest step
/// of both serve paths. `t0` anchors end-to-end latency at ingestion
/// start, so queue wait is part of every reported latency.
fn bin_request(cfg: ServerConfig, seq: u64, events: &[Event]) -> ClipJob {
    let t0 = Instant::now();
    let frames = bin_events(events, cfg.height, cfg.width, cfg.timesteps, cfg.bin_us);
    ClipJob { seq, t0, frames }
}

/// Functional serving engine: the single-threaded reference executor
/// ([`Network::step`]), the serving backend when neither the
/// cycle-level simulator nor PJRT execution is required. Vmem state is
/// allocated once and zeroed between clips, so each request is an
/// independent inference. The output is the final layer's accumulator
/// bank — bit-comparable across engine instances.
#[derive(Debug, Clone)]
pub struct ReferenceEngine {
    network: Network,
    state: NetworkState,
}

impl ReferenceEngine {
    /// Build an engine around a workload (allocates state once).
    pub fn new(network: Network) -> Result<Self> {
        let state = network.init_state()?;
        Ok(ReferenceEngine { network, state })
    }
}

impl Engine for ReferenceEngine {
    type Output = Vec<i32>;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Vec<i32>> {
        self.state.reset();
        for frame in clip {
            self.network.step(frame, &mut self.state)?;
        }
        Ok(self
            .state
            .vmems
            .last()
            .map(|m| m.as_slice().to_vec())
            .unwrap_or_default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvs::event::Polarity;

    struct CountEngine;

    impl Engine for CountEngine {
        type Output = u64;

        fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
            Ok(clip.iter().map(|p| p.count_spikes()).sum())
        }
    }

    fn burst(n: usize) -> Vec<Event> {
        (0..n)
            .map(|i| Event {
                y: (i % 8) as u16,
                x: (i / 8 % 8) as u16,
                polarity: Polarity::On,
                t_us: (i % 4) as u32 * 1000,
            })
            .collect()
    }

    fn small_cfg() -> ServerConfig {
        ServerConfig {
            height: 8,
            width: 8,
            timesteps: 4,
            bin_us: 1000,
            queue_depth: 2,
            ..Default::default()
        }
    }

    #[test]
    fn serves_in_order_with_metrics() {
        let server = InferenceServer::new(small_cfg());
        let reqs = vec![burst(10), burst(20), burst(5)];
        let (resp, metrics) = server.serve(reqs, &mut CountEngine).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0].id, 0);
        assert_eq!(resp[2].id, 2);
        assert_eq!(metrics.clips, 3);
        assert_eq!(metrics.frames, 12);
        // duplicate-collapsed spike counts are positive
        assert!(resp.iter().all(|r| r.output > 0));
    }

    #[test]
    fn failing_engine_propagates_error() {
        struct Bad;
        impl Engine for Bad {
            type Output = ();
            fn infer(&mut self, _: &[SpikePlane]) -> Result<()> {
                Err(Error::Runtime("boom".into()))
            }
        }
        let server = InferenceServer::new(small_cfg());
        assert!(server.serve(vec![burst(3)], &mut Bad).is_err());
    }

    #[test]
    fn empty_request_list() {
        let server = InferenceServer::new(small_cfg());
        let (resp, metrics) = server.serve(vec![], &mut CountEngine).unwrap();
        assert!(resp.is_empty());
        assert_eq!(metrics.clips, 0);
    }

    fn tiny_network() -> Network {
        use crate::quant::Precision;
        use crate::snn::layer::NeuronConfig;
        use crate::snn::network::NetworkBuilder;
        use crate::snn::tensor::Mat;
        let mut w1 = Mat::zeros(2 * 9, 4);
        for f in 0..18 {
            for k in 0..4 {
                w1.set(f, k, ((f * 5 + k) % 9) as i32 - 4);
            }
        }
        let w2 = Mat::zeros(4 * 4 * 4, 3);
        NetworkBuilder::new("serve-tiny", Precision::W4V7, 4, (2, 8, 8))
            .conv3x3(4, w1, NeuronConfig { theta: 3, ..Default::default() }, false)
            .unwrap()
            .pool(2, 2)
            .fc(3, w2, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    /// Satellite (c): a pool of one worker is bit-identical in output
    /// to the single-engine server on the same request stream.
    #[test]
    fn pool_of_one_bit_identical_to_single_engine() {
        let server = InferenceServer::new(small_cfg());
        let reqs: Vec<Vec<Event>> = (0..6).map(|i| burst(5 + i * 9)).collect();
        let net = tiny_network();

        let mut single = ReferenceEngine::new(net.clone()).unwrap();
        let (a, _) = server.serve(reqs.clone(), &mut single).unwrap();
        let (b, mb) = server
            .serve_pool(reqs, &PoolConfig::with_workers(1), |_| {
                ReferenceEngine::new(net.clone())
            })
            .unwrap();

        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.output, rb.output, "request {} diverged", ra.id);
        }
        assert_eq!(mb.workers.len(), 1);
        assert_eq!(mb.workers[0].clips, 6);
    }

    /// Satellite (a): responses come back in request order despite
    /// unequal worker latencies.
    #[test]
    fn pool_preserves_request_order_under_latency_skew() {
        struct Skew;
        impl Engine for Skew {
            type Output = u64;
            fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
                let n: u64 = clip.iter().map(|p| p.count_spikes()).sum();
                std::thread::sleep(Duration::from_millis((n % 4) * 3));
                Ok(n)
            }
        }
        let server = InferenceServer::new(small_cfg());
        let reqs: Vec<Vec<Event>> = (0..16).map(|i| burst(3 + i * 5)).collect();
        let mut reference = CountEngine;
        let (want, _) = server.serve(reqs.clone(), &mut reference).unwrap();
        let (got, metrics) = server
            .serve_pool(reqs, &PoolConfig::with_workers(4), |_| Ok(Skew))
            .unwrap();
        assert_eq!(got.len(), 16);
        for (i, r) in got.iter().enumerate() {
            assert_eq!(r.id, i as u64, "emission must restore arrival order");
            assert_eq!(r.output, want[i].output);
        }
        let total: u64 = metrics.workers.iter().map(|w| w.clips).sum();
        assert_eq!(total, 16);
        assert_eq!(metrics.clips, 16);
    }

    /// The third engine on the tier: selecting the pipelined
    /// functional engine via `ServerConfig::pipeline` /
    /// `PoolConfig::pipeline` yields bit-identical responses to the
    /// sequential reference on both serve paths.
    #[test]
    fn pipelined_engine_selected_by_config_is_bit_identical() {
        use super::super::pipeline::{FunctionalEngine, PipelineConfig};

        let net = tiny_network();
        let reqs: Vec<Vec<Event>> = (0..5).map(|i| burst(7 + i * 11)).collect();

        // baseline: reference engine on the single-engine path
        let server = InferenceServer::new(small_cfg());
        let mut single = ReferenceEngine::new(net.clone()).unwrap();
        let (want, _) = server.serve(reqs.clone(), &mut single).unwrap();

        // pipelined engine selected via ServerConfig, single-engine path
        let mut cfg = small_cfg();
        cfg.pipeline = Some(PipelineConfig {
            stages: 2,
            channel_depth: 1,
        });
        let pserver = InferenceServer::new(cfg);
        let mut piped =
            FunctionalEngine::from_config(net.clone(), pserver.cfg.pipeline, None, None).unwrap();
        let (got, mut metrics) = pserver.serve(reqs.clone(), &mut piped).unwrap();
        metrics.stages = piped.stage_metrics().to_vec();
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "request {} diverged", a.id);
        }
        assert_eq!(metrics.stages.len(), 2);
        assert!(metrics.pipeline_occupancy() > 0.0);

        // pipelined engines selected via PoolConfig, pool path
        let pool = PoolConfig {
            pipeline: cfg.pipeline,
            ..PoolConfig::with_workers(2)
        };
        let (pooled, _) = pserver
            .serve_pool(reqs, &pool, |_| {
                FunctionalEngine::from_config(net.clone(), pool.pipeline, None, None)
            })
            .unwrap();
        for (a, b) in want.iter().zip(&pooled) {
            assert_eq!(a.output, b.output, "pooled request {} diverged", a.id);
        }
    }

    /// The fourth engine on the tier: selecting the distributed shard
    /// constellation via `ServerConfig::distributed` /
    /// `PoolConfig::distributed` yields bit-identical responses to the
    /// sequential reference on both serve paths (DESIGN.md
    /// §Distributed).
    #[test]
    fn distributed_engine_selected_by_config_is_bit_identical() {
        use super::super::pipeline::FunctionalEngine;
        use crate::net::coordinator::DistributedConfig;

        let net = tiny_network();
        let reqs: Vec<Vec<Event>> = (0..5).map(|i| burst(9 + i * 13)).collect();

        // baseline: reference engine on the single-engine path
        let server = InferenceServer::new(small_cfg());
        let mut single = ReferenceEngine::new(net.clone()).unwrap();
        let (want, _) = server.serve(reqs.clone(), &mut single).unwrap();

        // distributed engine selected via ServerConfig
        let mut cfg = small_cfg();
        cfg.distributed = Some(DistributedConfig {
            shards: 2,
            window: 1,
            ..Default::default()
        });
        let dserver = InferenceServer::new(cfg);
        let mut dist =
            FunctionalEngine::from_config(net.clone(), None, dserver.cfg.distributed, None).unwrap();
        let (got, mut metrics) = dserver.serve(reqs.clone(), &mut dist).unwrap();
        metrics.stages = dist.stage_metrics().to_vec();
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "request {} diverged", a.id);
        }
        assert_eq!(metrics.stages.len(), 2);

        // distributed engines selected via PoolConfig: each pool
        // worker runs its own shard constellation
        let pool = PoolConfig {
            distributed: cfg.distributed,
            ..PoolConfig::with_workers(2)
        };
        let (pooled, _) = dserver
            .serve_pool(reqs, &pool, |_| {
                FunctionalEngine::from_config(net.clone(), None, pool.distributed, None)
            })
            .unwrap();
        for (a, b) in want.iter().zip(&pooled) {
            assert_eq!(a.output, b.output, "pooled request {} diverged", a.id);
        }
    }

    /// The fifth engine on the tier: selecting the batched bit-plane
    /// engine via `ServerConfig::batch` / `PoolConfig::batch` yields
    /// bit-identical responses to the sequential reference on both
    /// serve paths — the single-engine loop drains the ingest queue
    /// into lane batches, and each pool worker drains its own inbox
    /// (DESIGN.md §Perf).
    #[test]
    fn batched_engine_selected_by_config_is_bit_identical() {
        use super::super::pipeline::FunctionalEngine;

        let net = tiny_network();
        let reqs: Vec<Vec<Event>> = (0..9).map(|i| burst(5 + i * 7)).collect();

        // baseline: reference engine on the single-engine path
        let server = InferenceServer::new(small_cfg());
        let mut single = ReferenceEngine::new(net.clone()).unwrap();
        let (want, _) = server.serve(reqs.clone(), &mut single).unwrap();

        // batched engine selected via ServerConfig
        let mut cfg = small_cfg();
        cfg.batch = Some(BatchConfig::with_lanes(4));
        let bserver = InferenceServer::new(cfg);
        let mut batched =
            FunctionalEngine::from_config(net.clone(), None, None, bserver.cfg.batch).unwrap();
        assert_eq!(batched.max_batch(), 4);
        let (got, metrics) = bserver.serve(reqs.clone(), &mut batched).unwrap();
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "request {} diverged", a.id);
        }
        assert_eq!(metrics.clips, 9);

        // batched engines selected via PoolConfig: each worker drains
        // its inbox into lane batches of its own
        let pool = PoolConfig {
            batch: cfg.batch,
            ..PoolConfig::with_workers(2)
        };
        let (pooled, _) = bserver
            .serve_pool(reqs, &pool, |_| {
                FunctionalEngine::from_config(net.clone(), None, None, pool.batch)
            })
            .unwrap();
        for (a, b) in want.iter().zip(&pooled) {
            assert_eq!(a.output, b.output, "pooled request {} diverged", a.id);
        }
    }

    #[test]
    fn pool_propagates_engine_error() {
        struct Bad;
        impl Engine for Bad {
            type Output = ();
            fn infer(&mut self, _: &[SpikePlane]) -> Result<()> {
                Err(Error::Runtime("boom".into()))
            }
        }
        let server = InferenceServer::new(small_cfg());
        assert!(server
            .serve_pool(vec![burst(3); 4], &PoolConfig::with_workers(2), |_| Ok(Bad))
            .is_err());
    }
}
