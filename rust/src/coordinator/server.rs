//! Streaming inference server: the L3 request path.
//!
//! Three stages connected by bounded rendezvous channels — the
//! system-level analogue of the chip's asynchronous handshaking:
//! ingestion (event binning) → inference (simulated core or PJRT
//! golden model) → emission. Backpressure propagates through the
//! bounded channels; a slow inference stage throttles ingestion
//! instead of dropping events.
//!
//! Two inference stages are available: [`InferenceServer::serve`]
//! runs one engine on the calling thread (PJRT handles are not
//! `Send`), and [`InferenceServer::serve_pool`] shards clips across a
//! load-balanced worker pool ([`super::pool`]) while preserving
//! response order (DESIGN.md §Serve).

use crate::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, TryRecvError};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::dvs::binning::bin_events;
use crate::dvs::event::Event;
use crate::error::{Error, Result};
use crate::net::coordinator::DistributedConfig;
use crate::obs::metrics::hub;
use crate::obs::trace::{self, TraceId};
use crate::snn::network::{Network, NetworkState};
use crate::snn::spikes::SpikePlane;

use super::batch::BatchConfig;
use super::metrics::{Metrics, StageMetrics};
use super::pipeline::PipelineConfig;
use super::pool::{run_pool, ClipJob, PoolConfig};

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Frame height.
    pub height: usize,
    /// Frame width.
    pub width: usize,
    /// Timesteps per clip.
    pub timesteps: usize,
    /// Microseconds per timestep bin.
    pub bin_us: u32,
    /// Bounded queue depth between stages (backpressure window).
    pub queue_depth: usize,
    /// Select the timestep-pipelined functional engine (`Some`) over
    /// the sequential reference (`None`) when engines are built from
    /// this config (`FunctionalEngine::from_config`).
    pub pipeline: Option<PipelineConfig>,
    /// Select the distributed shard engine (`Some`) — layer groups on
    /// self-hosted shard threads behind the wire protocol (`net`,
    /// DESIGN.md §Distributed) — when engines are built from this
    /// config. Mutually exclusive with `pipeline`.
    pub distributed: Option<DistributedConfig>,
    /// Select the batched bit-plane engine (`Some`) — up to 64 clips
    /// packed into `u64` spike lanes and swept through the CIM rows
    /// once per batch ([`super::batch`], DESIGN.md §Perf) — when
    /// engines are built from this config. The serve loops then drain
    /// their queues in batches of up to [`BatchConfig::capacity`]
    /// clips. Mutually exclusive with `pipeline` and `distributed`.
    pub batch: Option<BatchConfig>,
    /// Deadline-bounded lane-batch assembly (DESIGN.md §Planner): when
    /// a batch-capable engine's batch is still filling and the ingest
    /// queue runs dry, hold the batch up to this many microseconds for
    /// stragglers with the **same timestep count** before dispatching.
    /// `0` (the default) keeps the legacy greedy behavior — dispatch
    /// the moment the queue is empty. Arrival order of responses is
    /// preserved either way.
    pub deadline_us: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            height: 64,
            width: 64,
            timesteps: 10,
            bin_us: 1000,
            queue_depth: 2,
            pipeline: None,
            distributed: None,
            batch: None,
            deadline_us: 0,
        }
    }
}

/// An inference engine pluggable into the server.
pub trait Engine {
    /// Engine output per clip.
    type Output: Send + 'static;

    /// Run one clip (frames indexed by timestep).
    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Self::Output>;

    /// Largest clip batch [`Engine::infer_batch`] can exploit in one
    /// call. The serve loops drain up to this many queued clips per
    /// dispatch; `1` (the default) keeps the per-clip path.
    fn max_batch(&self) -> usize {
        1
    }

    /// Run a batch of clips, one output per clip in order. The default
    /// loops [`Engine::infer`]; batch-capable engines (the lane-major
    /// [`super::batch::BatchedEngine`]) override it to amortize
    /// dispatch across the batch.
    fn infer_batch(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<Self::Output>> {
        clips.iter().map(|c| self.infer(c)).collect()
    }

    /// Per-stage counters accumulated so far, for staged engines (the
    /// timestep-pipelined and distributed backends); flat engines keep
    /// the empty default. The serve paths attach this to
    /// [`Metrics::stages`] after draining, so per-hop stall splits
    /// surface without reaching into the engine.
    fn stage_metrics(&self) -> Vec<StageMetrics> {
        Vec::new()
    }

    /// Completed failovers so far — clips re-homed onto a surviving
    /// replica after a replica death (the distributed backend); flat
    /// engines keep the 0 default. The serve paths surface this in
    /// [`Metrics::failovers`] (per-worker in
    /// [`super::metrics::WorkerMetrics::failovers`]), so recovery
    /// activity is visible without reaching into the engine.
    fn failovers(&self) -> u64 {
        0
    }
}

/// A completed request.
#[derive(Debug)]
pub struct Response<O> {
    /// Request id (arrival order).
    pub id: u64,
    /// Engine output.
    pub output: O,
    /// End-to-end latency (ingestion start → inference done).
    pub latency: Duration,
}

/// The streaming server.
pub struct InferenceServer {
    /// Configuration.
    pub cfg: ServerConfig,
}

impl InferenceServer {
    /// New server.
    pub fn new(cfg: ServerConfig) -> Self {
        InferenceServer { cfg }
    }

    /// Serve a stream of event bursts (one `Vec<Event>` per request)
    /// through a pipelined ingest → infer flow. The ingestion stage
    /// runs on its own thread; inference runs on the calling thread
    /// (PJRT handles are not `Send`), overlapping binning of request
    /// `n+1` with inference of request `n`.
    ///
    /// Returns responses in arrival order plus aggregate metrics.
    pub fn serve<E: Engine>(
        &self,
        requests: Vec<Vec<Event>>,
        engine: &mut E,
    ) -> Result<(Vec<Response<E::Output>>, Metrics)> {
        let cfg = self.cfg;
        let wall0 = Instant::now(); // lint: wall-clock
        let (tx, rx): (_, Receiver<ClipJob>) = sync_channel(cfg.queue_depth);

        let ingest = crate::sync::thread::spawn(move || {
            for (seq, events) in requests.into_iter().enumerate() {
                if tx.send(bin_request(cfg, seq as u64, &events)).is_err() {
                    return; // consumer dropped
                }
            }
        });

        let mut responses = Vec::new();
        let mut metrics = Metrics::new();
        // Batch-capable engines (`max_batch` > 1) assemble lane
        // batches of equal-length clips from the ingest queue —
        // holding a filling batch up to `deadline_us` for stragglers —
        // and amortize dispatch across the batch; a per-clip engine
        // degenerates to the old one-at-a-time loop.
        let cap = engine.max_batch().max(1);
        let deadline = Duration::from_micros(u64::from(cfg.deadline_us));
        let mut pending: VecDeque<ClipJob> = VecDeque::new();
        let mut closed = false;
        while let Some(jobs) = assemble_batch(&rx, &mut pending, cap, deadline, &mut closed) {
            let clips: Vec<&[SpikePlane]> = jobs.iter().map(|j| j.frames.as_slice()).collect();
            // Engine-internal spans attribute to the batch anchor's
            // trace; per-clip `infer` spans cover every member (the
            // same bracketing as the pool's worker loop).
            let _tscope = trace::bind(jobs[0].trace);
            let tr = trace::tracer();
            let infer0 = jobs
                .iter()
                .any(|j| tr.should_sample(j.trace))
                .then(|| tr.now_us());
            let outputs = engine.infer_batch(&clips)?;
            if let Some(s0) = infer0 {
                let end = tr.now_us();
                for j in &jobs {
                    tr.record_span(j.trace, "infer", s0, end);
                }
            }
            if outputs.len() != jobs.len() {
                return Err(Error::Runtime(format!(
                    "engine returned {} outputs for a {}-clip batch",
                    outputs.len(),
                    jobs.len()
                )));
            }
            for (job, output) in jobs.into_iter().zip(outputs) {
                let latency = job.t0.elapsed();
                observe_clip_done(job.trace, latency);
                metrics.record_clip(latency, job.frames.len() as u64);
                responses.push(Response {
                    id: job.seq,
                    output,
                    latency,
                });
            }
        }
        ingest
            .join()
            .map_err(|_| Error::Runtime("ingest thread panicked".into()))?;
        // Length bucketing can dispatch deferred clips out of arrival
        // order; the emission step restores it.
        responses.sort_by_key(|r| r.id);
        metrics.wall = wall0.elapsed();
        metrics.stages = engine.stage_metrics();
        metrics.failovers = engine.failovers();
        metrics.publish(hub());
        Ok((responses, metrics))
    }

    /// Serve a stream of event bursts through the **sharded pool
    /// tier**: ingestion (event binning, own thread) → dispatch into
    /// the pool's bounded per-worker inboxes → N engine workers →
    /// emission through a sequence-number reorder buffer.
    ///
    /// `factory` builds one engine per worker, inside that worker's
    /// thread. Responses come back in arrival order regardless of
    /// per-clip latency skew, and a saturated pool throttles the
    /// ingest channel instead of dropping clips (DESIGN.md §Serve).
    /// [`Metrics::workers`] carries the per-worker counters.
    pub fn serve_pool<E, F>(
        &self,
        requests: Vec<Vec<Event>>,
        pool: &PoolConfig,
        factory: F,
    ) -> Result<(Vec<Response<E::Output>>, Metrics)>
    where
        E: Engine,
        F: Fn(usize) -> Result<E> + Sync,
    {
        let cfg = self.cfg;
        let wall0 = Instant::now(); // lint: wall-clock
        crate::sync::thread::scope(|scope| {
            let (jtx, jrx) = sync_channel::<ClipJob>(cfg.queue_depth);
            let ingest = scope.spawn(move || {
                for (seq, events) in requests.into_iter().enumerate() {
                    if jtx.send(bin_request(cfg, seq as u64, &events)).is_err() {
                        return; // pool aborted; stop binning
                    }
                }
            });
            let run = run_pool(pool, jrx, &factory);
            ingest
                .join()
                .map_err(|_| Error::Runtime("ingest thread panicked".into()))?;
            let run = run?;
            let mut metrics = Metrics::new();
            let mut responses = Vec::with_capacity(run.clips.len());
            for done in run.clips {
                metrics.record_clip(done.latency, done.frames);
                responses.push(Response {
                    id: done.seq,
                    output: done.output,
                    latency: done.latency,
                });
            }
            metrics.workers = run.workers;
            metrics.stages = run.stages;
            metrics.wall = wall0.elapsed();
            metrics.publish(hub());
            Ok((responses, metrics))
        })
    }
}

/// Pull the next lane batch off the ingest channel: seed it with the
/// oldest deferred clip (or block for the next arrival), then gather
/// clips with the **same timestep count** — deferring mismatches to
/// `pending` — until the batch fills, the stream ends, or the assembly
/// deadline expires. A zero deadline keeps the greedy discipline:
/// dispatch the moment the queue runs dry. Returns `None` once the
/// stream is closed and nothing is deferred. (DESIGN.md §Planner,
/// deadline-bounded assembly; the pool twin is
/// `SharedQueue::drain_own_matching`.)
fn assemble_batch(
    rx: &Receiver<ClipJob>,
    pending: &mut VecDeque<ClipJob>,
    cap: usize,
    deadline: Duration,
    closed: &mut bool,
) -> Option<Vec<ClipJob>> {
    let first = match pending.pop_front() {
        Some(job) => job,
        None => {
            if *closed {
                return None;
            }
            match rx.recv() {
                Ok(job) => job,
                Err(_) => {
                    *closed = true;
                    return None;
                }
            }
        }
    };
    let timesteps = first.frames.len();
    let hold_until = Instant::now() + deadline; // lint: wall-clock
    let mut jobs = Vec::with_capacity(cap);
    jobs.push(first);
    // Deferred clips of a matching length join first, oldest first.
    let mut i = 0;
    while i < pending.len() && jobs.len() < cap {
        if pending[i].frames.len() == timesteps {
            jobs.push(pending.remove(i).expect("index in range"));
        } else {
            i += 1;
        }
    }
    while jobs.len() < cap && !*closed {
        match rx.try_recv() {
            Ok(job) if job.frames.len() == timesteps => jobs.push(job),
            Ok(job) => pending.push_back(job),
            Err(TryRecvError::Disconnected) => *closed = true,
            Err(TryRecvError::Empty) => {
                let now = Instant::now(); // lint: wall-clock
                if deadline.is_zero() || now >= hold_until {
                    break;
                }
                match rx.recv_timeout(hold_until - now) {
                    Ok(job) if job.frames.len() == timesteps => jobs.push(job),
                    Ok(job) => pending.push_back(job),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => *closed = true,
                }
            }
        }
    }
    Some(jobs)
}

/// Bin one request into a sequenced clip job — the shared ingest step
/// of both serve paths. `t0` anchors end-to-end latency at ingestion
/// start, so queue wait is part of every reported latency. The trace
/// identity is minted here — ingest is the clip's first contact with
/// the system — and rides in the job through every tier.
fn bin_request(cfg: ServerConfig, seq: u64, events: &[Event]) -> ClipJob {
    let tr = trace::tracer();
    let clip_trace = tr.mint();
    let _ingest = tr.span(clip_trace, "ingest");
    let t0 = Instant::now(); // lint: wall-clock
    let frames = bin_events(events, cfg.height, cfg.width, cfg.timesteps, cfg.bin_us);
    ClipJob {
        seq,
        t0,
        trace: clip_trace,
        frames,
    }
}

/// Emission-side observability shared by both serve paths (and the
/// pool's worker loop): record the root `clip` span — endpoints
/// reconstructed from the measured end-to-end latency, so ingest
/// queue wait is inside it — and feed the live latency histogram the
/// `spidr metrics` endpoint serves mid-run.
pub(crate) fn observe_clip_done(clip_trace: TraceId, latency: Duration) {
    let us = latency.as_micros() as u64;
    let tr = trace::tracer();
    if tr.should_sample(clip_trace) {
        let end = tr.now_us();
        tr.record_span(clip_trace, "clip", end.saturating_sub(us), end);
    }
    hub().observe_us("spidr_clip_latency_us", us);
}

/// Functional serving engine: the single-threaded reference executor
/// ([`Network::step`]), the serving backend when neither the
/// cycle-level simulator nor PJRT execution is required. Vmem state is
/// allocated once and zeroed between clips, so each request is an
/// independent inference. The output is the final layer's accumulator
/// bank — bit-comparable across engine instances.
#[derive(Debug, Clone)]
pub struct ReferenceEngine {
    network: Network,
    state: NetworkState,
}

impl ReferenceEngine {
    /// Build an engine around a workload (allocates state once).
    pub fn new(network: Network) -> Result<Self> {
        let state = network.init_state()?;
        Ok(ReferenceEngine { network, state })
    }
}

impl Engine for ReferenceEngine {
    type Output = Vec<i32>;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Vec<i32>> {
        self.state.reset();
        for frame in clip {
            self.network.step(frame, &mut self.state)?;
        }
        Ok(self
            .state
            .vmems
            .last()
            .map(|m| m.as_slice().to_vec())
            .unwrap_or_default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvs::event::Polarity;

    struct CountEngine;

    impl Engine for CountEngine {
        type Output = u64;

        fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
            Ok(clip.iter().map(|p| p.count_spikes()).sum())
        }
    }

    fn burst(n: usize) -> Vec<Event> {
        (0..n)
            .map(|i| Event {
                y: (i % 8) as u16,
                x: (i / 8 % 8) as u16,
                polarity: Polarity::On,
                t_us: (i % 4) as u32 * 1000,
            })
            .collect()
    }

    fn small_cfg() -> ServerConfig {
        ServerConfig {
            height: 8,
            width: 8,
            timesteps: 4,
            bin_us: 1000,
            queue_depth: 2,
            ..Default::default()
        }
    }

    #[test]
    fn serves_in_order_with_metrics() {
        let server = InferenceServer::new(small_cfg());
        let reqs = vec![burst(10), burst(20), burst(5)];
        let (resp, metrics) = server.serve(reqs, &mut CountEngine).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0].id, 0);
        assert_eq!(resp[2].id, 2);
        assert_eq!(metrics.clips, 3);
        assert_eq!(metrics.frames, 12);
        // duplicate-collapsed spike counts are positive
        assert!(resp.iter().all(|r| r.output > 0));
    }

    #[test]
    fn failing_engine_propagates_error() {
        struct Bad;
        impl Engine for Bad {
            type Output = ();
            fn infer(&mut self, _: &[SpikePlane]) -> Result<()> {
                Err(Error::Runtime("boom".into()))
            }
        }
        let server = InferenceServer::new(small_cfg());
        assert!(server.serve(vec![burst(3)], &mut Bad).is_err());
    }

    #[test]
    fn empty_request_list() {
        let server = InferenceServer::new(small_cfg());
        let (resp, metrics) = server.serve(vec![], &mut CountEngine).unwrap();
        assert!(resp.is_empty());
        assert_eq!(metrics.clips, 0);
    }

    fn tiny_network() -> Network {
        use crate::quant::Precision;
        use crate::snn::layer::NeuronConfig;
        use crate::snn::network::NetworkBuilder;
        use crate::snn::tensor::Mat;
        let mut w1 = Mat::zeros(2 * 9, 4);
        for f in 0..18 {
            for k in 0..4 {
                w1.set(f, k, ((f * 5 + k) % 9) as i32 - 4);
            }
        }
        let w2 = Mat::zeros(4 * 4 * 4, 3);
        NetworkBuilder::new("serve-tiny", Precision::W4V7, 4, (2, 8, 8))
            .conv3x3(4, w1, NeuronConfig { theta: 3, ..Default::default() }, false)
            .unwrap()
            .pool(2, 2)
            .fc(3, w2, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    /// Satellite (c): a pool of one worker is bit-identical in output
    /// to the single-engine server on the same request stream.
    #[test]
    fn pool_of_one_bit_identical_to_single_engine() {
        let server = InferenceServer::new(small_cfg());
        let reqs: Vec<Vec<Event>> = (0..6).map(|i| burst(5 + i * 9)).collect();
        let net = tiny_network();

        let mut single = ReferenceEngine::new(net.clone()).unwrap();
        let (a, _) = server.serve(reqs.clone(), &mut single).unwrap();
        let (b, mb) = server
            .serve_pool(reqs, &PoolConfig::with_workers(1), |_| {
                ReferenceEngine::new(net.clone())
            })
            .unwrap();

        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.output, rb.output, "request {} diverged", ra.id);
        }
        assert_eq!(mb.workers.len(), 1);
        assert_eq!(mb.workers[0].clips, 6);
    }

    /// Satellite (a): responses come back in request order despite
    /// unequal worker latencies.
    #[test]
    fn pool_preserves_request_order_under_latency_skew() {
        struct Skew;
        impl Engine for Skew {
            type Output = u64;
            fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
                let n: u64 = clip.iter().map(|p| p.count_spikes()).sum();
                std::thread::sleep(Duration::from_millis((n % 4) * 3));
                Ok(n)
            }
        }
        let server = InferenceServer::new(small_cfg());
        let reqs: Vec<Vec<Event>> = (0..16).map(|i| burst(3 + i * 5)).collect();
        let mut reference = CountEngine;
        let (want, _) = server.serve(reqs.clone(), &mut reference).unwrap();
        let (got, metrics) = server
            .serve_pool(reqs, &PoolConfig::with_workers(4), |_| Ok(Skew))
            .unwrap();
        assert_eq!(got.len(), 16);
        for (i, r) in got.iter().enumerate() {
            assert_eq!(r.id, i as u64, "emission must restore arrival order");
            assert_eq!(r.output, want[i].output);
        }
        let total: u64 = metrics.workers.iter().map(|w| w.clips).sum();
        assert_eq!(total, 16);
        assert_eq!(metrics.clips, 16);
    }

    /// The third engine on the tier: selecting the pipelined
    /// functional engine via `ServerConfig::pipeline` /
    /// `PoolConfig::pipeline` yields bit-identical responses to the
    /// sequential reference on both serve paths.
    #[test]
    fn pipelined_engine_selected_by_config_is_bit_identical() {
        use super::super::pipeline::{FunctionalEngine, PipelineConfig};

        let net = tiny_network();
        let reqs: Vec<Vec<Event>> = (0..5).map(|i| burst(7 + i * 11)).collect();

        // baseline: reference engine on the single-engine path
        let server = InferenceServer::new(small_cfg());
        let mut single = ReferenceEngine::new(net.clone()).unwrap();
        let (want, _) = server.serve(reqs.clone(), &mut single).unwrap();

        // pipelined engine selected via ServerConfig, single-engine path
        let mut cfg = small_cfg();
        cfg.pipeline = Some(PipelineConfig {
            stages: 2,
            channel_depth: 1,
        });
        let pserver = InferenceServer::new(cfg);
        let mut piped =
            FunctionalEngine::from_config(net.clone(), pserver.cfg.pipeline, None, None).unwrap();
        // serve attaches the engine's stage counters automatically
        let (got, metrics) = pserver.serve(reqs.clone(), &mut piped).unwrap();
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "request {} diverged", a.id);
        }
        assert_eq!(metrics.stages.len(), 2);
        assert!(metrics.pipeline_occupancy() > 0.0);

        // pipelined engines selected via PoolConfig, pool path
        let pool = PoolConfig {
            pipeline: cfg.pipeline,
            ..PoolConfig::with_workers(2)
        };
        let (pooled, _) = pserver
            .serve_pool(reqs, &pool, |_| {
                FunctionalEngine::from_config(net.clone(), pool.pipeline, None, None)
            })
            .unwrap();
        for (a, b) in want.iter().zip(&pooled) {
            assert_eq!(a.output, b.output, "pooled request {} diverged", a.id);
        }
    }

    /// The fourth engine on the tier: selecting the distributed shard
    /// constellation via `ServerConfig::distributed` /
    /// `PoolConfig::distributed` yields bit-identical responses to the
    /// sequential reference on both serve paths (DESIGN.md
    /// §Distributed).
    #[test]
    fn distributed_engine_selected_by_config_is_bit_identical() {
        use super::super::pipeline::FunctionalEngine;
        use crate::net::coordinator::DistributedConfig;

        let net = tiny_network();
        let reqs: Vec<Vec<Event>> = (0..5).map(|i| burst(9 + i * 13)).collect();

        // baseline: reference engine on the single-engine path
        let server = InferenceServer::new(small_cfg());
        let mut single = ReferenceEngine::new(net.clone()).unwrap();
        let (want, _) = server.serve(reqs.clone(), &mut single).unwrap();

        // distributed engine selected via ServerConfig
        let mut cfg = small_cfg();
        cfg.distributed = Some(DistributedConfig {
            shards: 2,
            window: 1,
            ..Default::default()
        });
        let dserver = InferenceServer::new(cfg);
        let mut dist =
            FunctionalEngine::from_config(net.clone(), None, dserver.cfg.distributed, None).unwrap();
        // Satellite (ISSUE 8): serve surfaces the distributed per-hop
        // counters in `Metrics::stages` without manual plumbing.
        let (got, metrics) = dserver.serve(reqs.clone(), &mut dist).unwrap();
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "request {} diverged", a.id);
        }
        assert_eq!(metrics.stages.len(), 2);
        assert!(
            metrics.stages.iter().all(|s| s.steps > 0),
            "per-hop counters must reflect the served clips"
        );

        // distributed engines selected via PoolConfig: each pool
        // worker runs its own shard constellation
        let pool = PoolConfig {
            distributed: cfg.distributed,
            ..PoolConfig::with_workers(2)
        };
        let (pooled, _) = dserver
            .serve_pool(reqs, &pool, |_| {
                FunctionalEngine::from_config(net.clone(), None, pool.distributed, None)
            })
            .unwrap();
        for (a, b) in want.iter().zip(&pooled) {
            assert_eq!(a.output, b.output, "pooled request {} diverged", a.id);
        }
    }

    /// The fifth engine on the tier: selecting the batched bit-plane
    /// engine via `ServerConfig::batch` / `PoolConfig::batch` yields
    /// bit-identical responses to the sequential reference on both
    /// serve paths — the single-engine loop drains the ingest queue
    /// into lane batches, and each pool worker drains its own inbox
    /// (DESIGN.md §Perf).
    #[test]
    fn batched_engine_selected_by_config_is_bit_identical() {
        use super::super::pipeline::FunctionalEngine;

        let net = tiny_network();
        let reqs: Vec<Vec<Event>> = (0..9).map(|i| burst(5 + i * 7)).collect();

        // baseline: reference engine on the single-engine path
        let server = InferenceServer::new(small_cfg());
        let mut single = ReferenceEngine::new(net.clone()).unwrap();
        let (want, _) = server.serve(reqs.clone(), &mut single).unwrap();

        // batched engine selected via ServerConfig
        let mut cfg = small_cfg();
        cfg.batch = Some(BatchConfig::with_lanes(4));
        let bserver = InferenceServer::new(cfg);
        let mut batched =
            FunctionalEngine::from_config(net.clone(), None, None, bserver.cfg.batch).unwrap();
        assert_eq!(batched.max_batch(), 4);
        let (got, metrics) = bserver.serve(reqs.clone(), &mut batched).unwrap();
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "request {} diverged", a.id);
        }
        assert_eq!(metrics.clips, 9);

        // batched engines selected via PoolConfig: each worker drains
        // its inbox into lane batches of its own
        let pool = PoolConfig {
            batch: cfg.batch,
            ..PoolConfig::with_workers(2)
        };
        let (pooled, _) = bserver
            .serve_pool(reqs, &pool, |_| {
                FunctionalEngine::from_config(net.clone(), None, None, pool.batch)
            })
            .unwrap();
        for (a, b) in want.iter().zip(&pooled) {
            assert_eq!(a.output, b.output, "pooled request {} diverged", a.id);
        }
    }

    #[test]
    fn pool_propagates_engine_error() {
        struct Bad;
        impl Engine for Bad {
            type Output = ();
            fn infer(&mut self, _: &[SpikePlane]) -> Result<()> {
                Err(Error::Runtime("boom".into()))
            }
        }
        let server = InferenceServer::new(small_cfg());
        assert!(server
            .serve_pool(vec![burst(3); 4], &PoolConfig::with_workers(2), |_| Ok(Bad))
            .is_err());
    }

    /// A synthetic clip job for driving `assemble_batch` directly.
    fn job(seq: u64, timesteps: usize) -> ClipJob {
        ClipJob {
            seq,
            t0: Instant::now(),
            trace: TraceId::NONE,
            frames: vec![SpikePlane::zeros(1, 2, 2); timesteps],
        }
    }

    /// Satellite (ISSUE 8): a trickle stream — arrivals slower than
    /// the deadline — never holds a filling batch past the deadline.
    /// The straggler lands 80 ms out; a 15 ms hold must dispatch the
    /// lone clip long before that.
    #[test]
    fn deadline_assembly_dispatches_trickle_arrivals_within_the_deadline() {
        let (tx, rx) = sync_channel::<ClipJob>(8);
        let mut pending = VecDeque::new();
        let mut closed = false;
        let t0 = Instant::now();
        let producer = crate::sync::thread::spawn(move || {
            tx.send(job(0, 4)).unwrap();
            std::thread::sleep(Duration::from_millis(80));
            tx.send(job(1, 4)).unwrap();
        });
        let hold = Duration::from_millis(15);
        let batch = assemble_batch(&rx, &mut pending, 64, hold, &mut closed).unwrap();
        assert_eq!(batch.len(), 1, "the hold must expire, not wait for the straggler");
        assert_eq!(batch[0].seq, 0);
        assert!(
            t0.elapsed() < Duration::from_millis(60),
            "dispatch must beat the 80 ms straggler: {:?}",
            t0.elapsed()
        );
        let batch = assemble_batch(&rx, &mut pending, 64, hold, &mut closed).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].seq, 1);
        producer.join().unwrap();
        assert!(
            assemble_batch(&rx, &mut pending, 64, hold, &mut closed).is_none(),
            "a closed empty stream ends the loop"
        );
    }

    /// Satellite (ISSUE 8): length bucketing packs an interleaved
    /// mixed-length queue into single-length batches at least as
    /// tightly as sorting the whole queue by length and cutting
    /// cap-sized batches would (the offline upper bound on occupancy).
    #[test]
    fn deadline_assembly_packs_mixed_lengths_at_least_as_well_as_sorted_greedy() {
        let lens = [4usize, 6, 4, 6, 4, 6, 4, 6, 4, 6];
        let cap = 4usize;
        let (tx, rx) = sync_channel::<ClipJob>(lens.len());
        for (i, &t) in lens.iter().enumerate() {
            tx.send(job(i as u64, t)).unwrap();
        }
        drop(tx);

        let mut by_len = std::collections::BTreeMap::new();
        for &t in &lens {
            *by_len.entry(t).or_insert(0usize) += 1;
        }
        let sorted_greedy_batches: usize = by_len.values().map(|n| n.div_ceil(cap)).sum();

        let mut pending = VecDeque::new();
        let mut closed = false;
        let mut batches = Vec::new();
        let mut seqs = Vec::new();
        while let Some(b) = assemble_batch(&rx, &mut pending, cap, Duration::ZERO, &mut closed) {
            assert!(
                b.iter().all(|j| j.frames.len() == b[0].frames.len()),
                "every assembled batch is single-length"
            );
            assert!(b.len() <= cap);
            seqs.extend(b.iter().map(|j| j.seq));
            batches.push(b.len());
        }
        assert_eq!(batches.iter().sum::<usize>(), lens.len(), "no clip lost");
        seqs.sort_unstable();
        assert_eq!(seqs, (0..lens.len() as u64).collect::<Vec<_>>());
        assert!(
            batches.len() <= sorted_greedy_batches,
            "{} batches vs sorted greedy's {}",
            batches.len(),
            sorted_greedy_batches
        );
    }

    /// Satellite (ISSUE 8): with a deadline configured, the serve path
    /// still returns responses in arrival order and serves every clip
    /// exactly once through batched dispatch.
    #[test]
    fn deadline_serve_preserves_arrival_order() {
        struct Probe {
            sizes: Vec<usize>,
        }
        impl Engine for Probe {
            type Output = u64;
            fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
                Ok(clip.iter().map(|p| p.count_spikes()).sum())
            }
            fn max_batch(&self) -> usize {
                8
            }
            fn infer_batch(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<u64>> {
                self.sizes.push(clips.len());
                clips.iter().map(|c| self.infer(c)).collect()
            }
        }
        let mut cfg = small_cfg();
        cfg.deadline_us = 5_000;
        let server = InferenceServer::new(cfg);
        let reqs: Vec<Vec<Event>> = (0..10).map(|i| burst(3 + i * 7)).collect();
        let mut reference = CountEngine;
        let (want, _) = InferenceServer::new(small_cfg())
            .serve(reqs.clone(), &mut reference)
            .unwrap();
        let mut probe = Probe { sizes: Vec::new() };
        let (resp, metrics) = server.serve(reqs, &mut probe).unwrap();
        assert_eq!(resp.len(), 10);
        for (i, r) in resp.iter().enumerate() {
            assert_eq!(r.id, i as u64, "responses must come back in arrival order");
            assert_eq!(r.output, want[i].output);
        }
        assert_eq!(metrics.clips, 10);
        assert_eq!(probe.sizes.iter().sum::<usize>(), 10);
        assert!(
            probe.sizes.iter().any(|&s| s > 1),
            "the deadline hold must have assembled at least one real batch: {:?}",
            probe.sizes
        );
    }
}
