//! Fixed-point arithmetic contract (mirror of `python/compile/quantize.py`).
//!
//! SpiDR stores weights at `B_w ∈ {4, 6, 8}` bits and membrane
//! potentials at `B_v = 2·B_w − 1 ∈ {7, 11, 15}` bits (paper §II-A),
//! both signed two's-complement. The B_v-bit column adder chain *wraps*
//! on overflow; modular addition being associative/commutative is what
//! lets the even/odd FIFO batching and Mode-2 partial-Vmem hopping
//! reorder operations freely without changing results (DESIGN.md §2).

use crate::error::{Error, Result};

/// A reconfigurable weight/Vmem precision operating point (Fig. 8a).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// 4-bit weights / 7-bit Vmems.
    W4V7,
    /// 6-bit weights / 11-bit Vmems.
    W6V11,
    /// 8-bit weights / 15-bit Vmems.
    W8V15,
}

/// All supported precision pairs, in Fig. 8a order.
pub const ALL_PRECISIONS: [Precision; 3] =
    [Precision::W4V7, Precision::W6V11, Precision::W8V15];

impl Precision {
    /// Construct from a weight bit-width.
    pub fn from_weight_bits(wb: u32) -> Result<Self> {
        match wb {
            4 => Ok(Precision::W4V7),
            6 => Ok(Precision::W6V11),
            8 => Ok(Precision::W8V15),
            _ => Err(Error::config(format!(
                "unsupported weight precision {wb} (supported: 4, 6, 8)"
            ))),
        }
    }

    /// Weight bit-width `B_w`.
    pub fn weight_bits(self) -> u32 {
        match self {
            Precision::W4V7 => 4,
            Precision::W6V11 => 6,
            Precision::W8V15 => 8,
        }
    }

    /// Vmem bit-width `B_v = 2·B_w − 1`.
    pub fn vmem_bits(self) -> u32 {
        2 * self.weight_bits() - 1
    }

    /// Minimum representable weight value.
    pub fn weight_min(self) -> i32 {
        -(1 << (self.weight_bits() - 1))
    }

    /// Maximum representable weight value.
    pub fn weight_max(self) -> i32 {
        (1 << (self.weight_bits() - 1)) - 1
    }

    /// Minimum representable Vmem value.
    pub fn vmem_min(self) -> i32 {
        -(1 << (self.vmem_bits() - 1))
    }

    /// Maximum representable Vmem value.
    pub fn vmem_max(self) -> i32 {
        (1 << (self.vmem_bits() - 1)) - 1
    }

    /// Output neurons stored per 48-bit weight row: `48 / B_w` (eq. 1).
    pub fn neurons_per_row(self) -> usize {
        48 / self.weight_bits() as usize
    }

    /// Output neurons per compute macro: `(48 / B_w) · 16` (eq. 1) —
    /// 16 is the effective Vmem row count (32 physical rows, two per
    /// staggered B_v-bit entry).
    pub fn neurons_per_macro(self) -> usize {
        self.neurons_per_row() * 16
    }
}

/// Two's-complement wrap of an i32 to `bits` bits (arithmetic
/// shift-up/shift-down pair — exactly the adder chain's sign behavior).
///
/// Width-safe across the whole `i32` register: `bits` is clamped to
/// `1..=32` (at 32 the wrap is the identity; a zero width has no
/// signed range and is treated as 1 bit rather than shifting by 32,
/// which would panic in debug builds).
#[inline(always)]
pub fn wrap_to_bits(x: i32, bits: u32) -> i32 {
    let shift = 32 - bits.clamp(1, 32);
    (x << shift) >> shift
}

/// Saturating clamp to a signed `bits`-bit range (optional macro mode).
///
/// Width-safe: the old `(1 << (bits - 1)) - 1` overflowed in debug
/// builds at `bits = 32` (shift by 31 makes `i32::MIN`, then `- 1`
/// wraps) and underflowed at `bits = 0` (shift by `u32::MAX`). The
/// bounds are now derived by shifting *down* from `i32::MAX`, which is
/// exact for every width: `bits = 32` clamps to the full i32 range
/// (identity) and `bits` is clamped to `1..=32` like [`wrap_to_bits`]
/// (a 1-bit signed range is `[-1, 0]`).
#[inline(always)]
pub fn saturate_to_bits(x: i32, bits: u32) -> i32 {
    let bits = bits.clamp(1, 32);
    let hi = i32::MAX >> (32 - bits);
    let lo = -hi - 1;
    x.clamp(lo, hi)
}

/// Overflow behavior of the column adder chain.
///
/// `Wrap` is the architectural contract (order-independent, bit-exact
/// vs. the JAX golden model); `Saturate` is provided for ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Overflow {
    /// Two's-complement modular wrap (default).
    #[default]
    Wrap,
    /// Clamp at the representable range.
    Saturate,
}

impl Overflow {
    /// Apply the overflow policy at a given bit width.
    #[inline(always)]
    pub fn apply(self, x: i32, bits: u32) -> i32 {
        match self {
            Overflow::Wrap => wrap_to_bits(x, bits),
            Overflow::Saturate => saturate_to_bits(x, bits),
        }
    }
}

/// Symmetric per-tensor weight quantization: `w ≈ w_q · scale`.
pub fn quantize_weights(w: &[f32], precision: Precision) -> (Vec<i32>, f64) {
    let max_abs = w.iter().fold(0.0f64, |m, &x| m.max((x as f64).abs()));
    if max_abs == 0.0 {
        return (vec![0; w.len()], 1.0);
    }
    let scale = max_abs / precision.weight_max() as f64;
    let q = w
        .iter()
        .map(|&x| {
            ((x as f64 / scale).round() as i32)
                .clamp(precision.weight_min(), precision.weight_max())
        })
        .collect();
    (q, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;

    #[test]
    fn precision_tables() {
        assert_eq!(Precision::W4V7.vmem_bits(), 7);
        assert_eq!(Precision::W6V11.vmem_bits(), 11);
        assert_eq!(Precision::W8V15.vmem_bits(), 15);
        assert_eq!(Precision::W4V7.neurons_per_row(), 12);
        assert_eq!(Precision::W6V11.neurons_per_row(), 8);
        assert_eq!(Precision::W8V15.neurons_per_row(), 6);
        assert_eq!(Precision::W4V7.neurons_per_macro(), 192);
        assert_eq!(Precision::W4V7.weight_min(), -8);
        assert_eq!(Precision::W8V15.vmem_max(), 16383);
    }

    #[test]
    fn from_weight_bits_rejects_unsupported() {
        assert!(Precision::from_weight_bits(5).is_err());
        assert!(Precision::from_weight_bits(4).is_ok());
    }

    #[test]
    fn wrap_known_values() {
        // Mirrors python test_quantize.py::test_wrap_known_values.
        let xs = [63, 64, 127, 128, -64, -65];
        let expect = [63, -64, -1, 0, -64, 63];
        for (x, e) in xs.iter().zip(expect) {
            assert_eq!(wrap_to_bits(*x, 7), e);
        }
    }

    #[test]
    fn wrap_matches_modular_arithmetic() {
        // Sweeps every register width 1..=32, not just the Vmem
        // operating points — the codec must be total over widths.
        check("wrap_mod", 1000, |g| {
            let bits = 1 + g.index(32) as u32;
            let x = g.i32_in(i32::MIN..=i32::MAX);
            let m = 1i64 << bits;
            let expected =
                ((x as i64 + m / 2).rem_euclid(m) - m / 2) as i32;
            wrap_to_bits(x, bits) == expected
        });
    }

    #[test]
    fn wrap_is_order_independent() {
        // wrap(wrap(a+b)+c) == wrap(a+b+c): the associativity property
        // that makes even/odd batching and Mode-2 hopping sound.
        check("wrap_assoc", 500, |g| {
            let bits = *g.choose(&[7u32, 11, 15]);
            let (a, b, c) = (
                g.i32_in(-100_000..=100_000),
                g.i32_in(-100_000..=100_000),
                g.i32_in(-100_000..=100_000),
            );
            wrap_to_bits(wrap_to_bits(a + b, bits) + c, bits)
                == wrap_to_bits(a + b + c, bits)
        });
    }

    #[test]
    fn saturate_clamps() {
        assert_eq!(saturate_to_bits(1000, 7), 63);
        assert_eq!(saturate_to_bits(-1000, 7), -64);
        assert_eq!(saturate_to_bits(5, 7), 5);
    }

    /// Regression: the old `(1 << (bits - 1)) - 1` clamp overflowed in
    /// debug builds at `bits = 32` and shifted by `u32::MAX` at
    /// `bits = 0`; both widths must now be total.
    #[test]
    fn saturate_and_wrap_are_total_at_the_width_edges() {
        // 32 bits: the full register — both ops are the identity.
        for x in [i32::MIN, -1, 0, 1, i32::MAX] {
            assert_eq!(saturate_to_bits(x, 32), x);
            assert_eq!(wrap_to_bits(x, 32), x);
        }
        // 1 bit: the signed range is [-1, 0].
        assert_eq!(saturate_to_bits(7, 1), 0);
        assert_eq!(saturate_to_bits(-7, 1), -1);
        assert_eq!(wrap_to_bits(2, 1), 0);
        assert_eq!(wrap_to_bits(1, 1), -1);
        // 0 bits has no signed range; clamped to 1 bit, never a panic.
        assert_eq!(saturate_to_bits(7, 0), 0);
        assert_eq!(saturate_to_bits(-7, 0), -1);
        assert_eq!(wrap_to_bits(3, 0), wrap_to_bits(3, 1));
    }

    /// Saturation across every width 1..=32 matches the i64-domain
    /// clamp to `[-2^(bits-1), 2^(bits-1) - 1]`.
    #[test]
    fn prop_saturate_matches_i64_clamp_all_widths() {
        check("saturate_widths", 1000, |g| {
            let bits = 1 + g.index(32) as u32;
            let x = g.i32_in(i32::MIN..=i32::MAX);
            let hi = (1i64 << (bits - 1)) - 1;
            let lo = -(1i64 << (bits - 1));
            saturate_to_bits(x, bits) as i64 == (x as i64).clamp(lo, hi)
        });
    }

    #[test]
    fn quantize_bounds_and_roundtrip() {
        let w: Vec<f32> = (-32..32).map(|i| i as f32 * 0.017).collect();
        for p in ALL_PRECISIONS {
            let (q, scale) = quantize_weights(&w, p);
            for (&qi, &wi) in q.iter().zip(&w) {
                assert!(qi >= p.weight_min() && qi <= p.weight_max());
                assert!((qi as f64 * scale - wi as f64).abs() <= scale * 0.5 + 1e-9);
            }
        }
    }

    #[test]
    fn quantize_zero_tensor() {
        let (q, scale) = quantize_weights(&[0.0; 9], Precision::W4V7);
        assert_eq!(scale, 1.0);
        assert!(q.iter().all(|&x| x == 0));
    }
}
