//! `spidr` — CLI for the SpiDR accelerator reproduction.
//!
//! Subcommands (hand-rolled parsing; clap is not in this environment):
//!
//! ```text
//! spidr chip     [--wb 4|6|8] [--sparsity S] [--corner low|high]
//!                  print the simulated chip-summary operating point
//! spidr gesture  [--wb 4] [--clips N] [--artifacts DIR]
//!                  run synthetic gesture clips end to end (golden PJRT
//!                  model + cycle simulator), report accuracy + energy
//! spidr flow     [--wb 4] [--clips N] [--artifacts DIR]
//!                  run synthetic flow clips, report AEE + energy
//! spidr map      [--task gesture|flow] [--wb 4] [--artifacts DIR]
//!                  show the layer-by-layer core mapping
//! spidr shard    [--listen HOST:PORT] [--workload pipeline-demo|serving-demo]
//!                [--timesteps N] [--sessions N] [--protocol 2|3]
//!                [--trace FILE] [--metrics-listen HOST:PORT]
//!                  host layer-group shards for a distributed
//!                  coordinator (DESIGN.md §Distributed); serves
//!                  sessions forever, or exactly N with --sessions.
//!                  Without --workload the shard starts blank and is
//!                  provisioned over the wire by the coordinator's
//!                  weight push. --protocol 2 pins the host to the
//!                  scalar-only v2 grammar (lane batches rejected),
//!                  which forces a v3 coordinator into scalar fallback.
//!                  --trace writes a Chrome-trace JSON of spans the
//!                  coordinator did not pull after every session;
//!                  --metrics-listen serves Prometheus text on a
//!                  scrape socket (DESIGN.md §Observability)
//! spidr metrics  [--connect HOST:PORT]
//!                  scrape a live `--metrics-listen` endpoint (shard or
//!                  example process) and print the Prometheus snapshot
//! spidr plan     [--workload pipeline-demo|serving-demo] [--timesteps N]
//!                [--links MBxUS,MBxUS,...]
//!                  print the topology-aware deployment plan (DESIGN.md
//!                  §Planner) for a demo workload over candidate shard
//!                  sites, one per --links entry: serialization
//!                  bandwidth in MB/s `x` one-way latency in µs
//!                  (default: three loopback sites)
//! spidr lint     [--root DIR]
//!                  scan the repo tree (default: the working
//!                  directory) for concurrency-correctness invariant
//!                  violations (`spidr::lint`, DESIGN.md
//!                  §Correctness); prints each finding with a fix
//!                  hint and exits nonzero if any
//! ```
#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::process::ExitCode;

use spidr::coordinator::{Mapper, NetworkCompiler};
use spidr::dvs::flow_scene::{average_endpoint_error, make_flow_scene, FlowSceneConfig};
use spidr::dvs::gesture::{make_gesture, GestureConfig, NUM_GESTURE_CLASSES};
use spidr::energy::calibration::measure;
use spidr::energy::model::Corner;
use spidr::error::{Error, Result};
use spidr::net::wire::{MIN_VERSION, VERSION};
use spidr::net::{plan_deployment, LinkSpec, PlannerConfig, ShardHost, TcpTransport};
use spidr::obs::{hub, scrape, tracer, MetricsServer};
use spidr::quant::Precision;
use spidr::runtime::{ArtifactStore, GoldenModel};
use spidr::sim::SimConfig;
use spidr::snn::network::{
    demo_pipeline_network, demo_serving_network, flow_network, gesture_network,
};
use spidr::snn::WeightBundle;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            out.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    out
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn cmd_chip(flags: &HashMap<String, String>) -> Result<()> {
    let wb: u32 = flag(flags, "wb", 4);
    let sparsity: f64 = flag(flags, "sparsity", 0.95);
    let corner = match flags.get("corner").map(|s| s.as_str()) {
        Some("high") => Corner::HIGH,
        _ => Corner::LOW,
    };
    let p = Precision::from_weight_bits(wb)?;
    let op = measure(p, corner, sparsity);
    println!("SpiDR simulated operating point");
    println!("  precision   : {}/{}-bit", p.weight_bits(), p.vmem_bits());
    println!("  corner      : {} MHz @ {} V", corner.freq_mhz, corner.voltage);
    println!("  sparsity    : {:.1} %", op.sparsity * 100.0);
    println!("  throughput  : {:.2} GOPS", op.gops);
    println!("  efficiency  : {:.2} TOPS/W", op.tops_per_watt);
    println!("  power       : {:.2} mW", op.power_mw);
    Ok(())
}

fn cmd_map(flags: &HashMap<String, String>) -> Result<()> {
    let wb: u32 = flag(flags, "wb", 4);
    let task = flags.get("task").cloned().unwrap_or_else(|| "flow".into());
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".into());
    let p = Precision::from_weight_bits(wb)?;
    let bundle = WeightBundle::load(format!("{dir}/weights/{task}_w{wb}.swb"))?;
    let net = match task.as_str() {
        "gesture" => gesture_network(&bundle, p, 64, 64, 10)?,
        _ => flow_network(&bundle, p, 288, 384, 10)?,
    };
    let mapper = Mapper::new(p);
    println!("layer mapping for '{task}' at {wb}-bit (deploy geometry):");
    for (i, layer) in net.layers.iter().enumerate() {
        if !layer.has_state() {
            println!("  L{i}: pool {}x{} (input loader)", layer.kh, layer.stride);
            continue;
        }
        let m = mapper.map_layer(layer)?;
        println!(
            "  L{i}: {:?} fan-in {:4} -> {:?}, rows/CU {:?}, {} groups, \
             {} passes, {} tiles, {:.0}% rows used",
            layer.kind,
            layer.fan_in(),
            m.mode,
            m.rows_per_cu,
            m.channel_groups,
            m.passes,
            m.tiles,
            m.row_utilization * 100.0
        );
    }
    Ok(())
}

/// Host layer-group shards: listen for coordinator sessions and serve
/// each through a [`ShardHost`] over TCP. By default the host starts
/// **blank** — no local artifact; the coordinator's first `LoadGroup`
/// pushes the serialized workload over the wire and assigns which
/// layer group this process owns (weights cross once, then stay
/// pinned). `--workload pipeline-demo|serving-demo` materializes a
/// demo workload locally instead (the pre-push behavior).
///
/// Observability hooks (DESIGN.md §Observability): `--trace FILE`
/// rewrites FILE with a Chrome-trace JSON after every session,
/// covering spans a coordinator did **not** pull over the sideband
/// (a traced coordinator flushes them itself, so the two exports never
/// double-count); `--metrics-listen HOST:PORT` serves the process-wide
/// Prometheus snapshot — session/clip/frame counters — for
/// `spidr metrics` or any Prometheus scraper.
fn cmd_shard(flags: &HashMap<String, String>) -> Result<()> {
    let listen = flags
        .get("listen")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7400".into());
    let timesteps: usize = flag(flags, "timesteps", 12);
    let sessions: u64 = flag(flags, "sessions", 0); // 0 = serve forever
    let trace_out = flags.get("trace").filter(|s| !s.is_empty()).cloned();
    let metrics_listen = flags.get("metrics-listen").filter(|s| !s.is_empty());
    let protocol: u16 = flag(flags, "protocol", VERSION);
    if !(MIN_VERSION..=VERSION).contains(&protocol) {
        return Err(Error::config(format!(
            "unsupported --protocol {protocol} (supported: {MIN_VERSION}..={VERSION})"
        )));
    }
    let net = match flags.get("workload").map(|s| s.as_str()) {
        None | Some("") => None, // blank: provisioned by the coordinator
        Some("pipeline-demo") => Some(demo_pipeline_network(timesteps)?),
        Some("serving-demo") => Some(demo_serving_network(timesteps)?),
        Some(other) => {
            return Err(Error::config(format!(
                "unknown shard workload '{other}' (pipeline-demo|serving-demo, \
                 or omit --workload to be provisioned over the wire)"
            )));
        }
    };
    if trace_out.is_some() {
        let tr = tracer();
        tr.enable(1);
        tr.set_process_label("shard");
    }
    let _metrics_server = match metrics_listen {
        Some(addr) => {
            let server = MetricsServer::spawn(addr, hub())?;
            eprintln!("spidr-shard: serving metrics on {}", server.local_addr());
            Some(server)
        }
        None => None,
    };
    let listener = std::net::TcpListener::bind(&listen)?;
    match &net {
        Some(n) => eprintln!(
            "spidr-shard: hosting '{}' ({timesteps} steps) on {}",
            n.name,
            listener.local_addr()?
        ),
        None => eprintln!(
            "spidr-shard: blank v{protocol} host on {} (waiting for a coordinator weight push)",
            listener.local_addr()?
        ),
    }
    let mut served = 0u64;
    loop {
        let (stream, peer) = listener.accept()?;
        let mut link = TcpTransport::from_stream(stream);
        let mut host = match &net {
            Some(n) => ShardHost::new(n.clone()),
            None => ShardHost::blank("blank-shard"),
        }
        .with_protocol(protocol);
        match host.serve(&mut link) {
            Ok(report) => {
                hub().counter_add("spidr_shard_sessions_total", 1);
                hub().counter_add("spidr_shard_clips_total", report.clips);
                hub().counter_add("spidr_shard_frames_total", report.frames);
                hub().counter_add("spidr_shard_lane_batches_total", report.batches);
                eprintln!(
                    "spidr-shard: session from {peer} done ({} clips, {} frames, span {:?})",
                    report.clips,
                    report.frames,
                    host.span()
                );
            }
            Err(e) => eprintln!("spidr-shard: session from {peer} failed: {e}"),
        }
        served += 1;
        if let Some(path) = &trace_out {
            // Spans a traced coordinator pulled over the sideband are
            // gone from the host by now — only the leftovers land here,
            // so a coordinator-side export never double-counts them.
            let leftover = host.take_trace_spans();
            if !leftover.is_empty() {
                tracer().inject(&format!("session-{served}"), leftover, 0);
            }
            std::fs::write(path, tracer().to_chrome_json())?;
        }
        if sessions > 0 && served >= sessions {
            return Ok(());
        }
    }
}

/// Scrape a live `--metrics-listen` endpoint and print the Prometheus
/// text snapshot — counters, gauges, and the log-bucketed latency
/// histograms (DESIGN.md §Observability).
fn cmd_metrics(flags: &HashMap<String, String>) -> Result<()> {
    let addr = flags
        .get("connect")
        .filter(|s| !s.is_empty())
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:9464".into());
    print!("{}", scrape(&addr)?);
    Ok(())
}

/// Print the topology-aware deployment plan (DESIGN.md §Planner) for a
/// demo workload over a set of candidate shard sites: layer-group
/// placement, replica spread, per-hop protocol windows, and the modeled
/// clip makespan the choice minimizes.
fn cmd_plan(flags: &HashMap<String, String>) -> Result<()> {
    let timesteps: usize = flag(flags, "timesteps", 12);
    let net = match flags.get("workload").map(|s| s.as_str()) {
        Some("pipeline-demo") => demo_pipeline_network(timesteps)?,
        None | Some("") | Some("serving-demo") => demo_serving_network(timesteps)?,
        Some(other) => {
            return Err(Error::config(format!(
                "unknown plan workload '{other}' (pipeline-demo|serving-demo)"
            )));
        }
    };
    let sites: Vec<LinkSpec> = match flags.get("links").filter(|s| !s.is_empty()) {
        None => vec![LinkSpec::loopback(); 3],
        Some(spec) => spec
            .split(',')
            .map(|entry| {
                let parse = |s: &str| {
                    s.trim().parse::<u64>().map_err(|_| {
                        Error::config(format!(
                            "bad link '{entry}' (want MB/s `x` µs, e.g. 100x1500)"
                        ))
                    })
                };
                let (bw, lat) = entry.split_once('x').ok_or_else(|| {
                    Error::config(format!(
                        "bad link '{entry}' (want MB/s `x` µs, e.g. 100x1500)"
                    ))
                })?;
                Ok(LinkSpec::new(parse(bw)?.max(1) << 20, parse(lat)?))
            })
            .collect::<Result<Vec<LinkSpec>>>()?,
    };
    let plan = plan_deployment(&net, &sites, &PlannerConfig::default())?;
    println!(
        "deployment plan for '{}' ({timesteps} steps) over {} candidate sites:",
        net.name,
        sites.len()
    );
    for (h, hop) in plan.hops.iter().enumerate() {
        let spec = sites[hop.site];
        println!(
            "  hop {h}: layers {}..{} -> site {} ({} MB/s, {} us) \
             window {} replicas {} | compute {:.1} us, frames {}B in / {}B out, \
             serv {:.1} us, rtt {:.1} us, steady {:.1} us",
            hop.group.0,
            hop.group.1,
            hop.site,
            spec.bandwidth_bytes_per_s >> 20,
            spec.latency_us,
            hop.window,
            hop.replicas,
            hop.compute_us,
            hop.in_bytes,
            hop.out_bytes,
            hop.serv_us,
            hop.rtt_us,
            hop.steady_us,
        );
    }
    println!(
        "  modeled clip makespan: {:.1} us ({} groups over {} sites)",
        plan.modeled_clip_us,
        plan.groups.len(),
        sites.len()
    );
    Ok(())
}

fn cmd_gesture(flags: &HashMap<String, String>) -> Result<()> {
    let wb: u32 = flag(flags, "wb", 4);
    let clips: usize = flag(flags, "clips", 6);
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".into());
    let p = Precision::from_weight_bits(wb)?;

    let mut store = ArtifactStore::open(&dir)?;
    let name = format!("gesture_w{wb}");
    let mut golden = GoldenModel::new(&store, &name)?;
    let (c, h, w) = golden.frame_shape();
    assert_eq!(c, 2, "gesture artifact must be 2-channel");
    let cfg = GestureConfig {
        height: h,
        width: w,
        timesteps: golden.timesteps,
        noise_rate: 0.01,
    };

    // Cycle simulator on the same network for energy/cycles.
    let bundle = WeightBundle::load(store.swb_path("gesture", wb))?;
    let net = gesture_network(&bundle, p, h, w, golden.timesteps)?;
    let compiled = NetworkCompiler::compile(net, SimConfig::timing_only(p))?;

    let mut correct = 0;
    let mut total_tops_w = 0.0;
    for i in 0..clips {
        let label = i % NUM_GESTURE_CLASSES;
        let clip = make_gesture(label, 7000 + i as u64, &cfg);
        golden.run_clip(&mut store, &clip.frames)?;
        let pred = golden.argmax();
        correct += usize::from(pred == label);

        let mut state = compiled.network.init_state()?;
        let report = compiled.run_clip(&clip.frames, &mut state)?;
        total_tops_w += report.total.tops_per_watt(Corner::LOW);
        println!(
            "clip {i}: label {label} pred {pred} | {:.0} kcycles, {:.2} uJ, {:.2} TOPS/W",
            report.total.cycles as f64 / 1e3,
            report.total.total_energy_pj(Corner::LOW) / 1e6,
            report.total.tops_per_watt(Corner::LOW),
        );
    }
    println!(
        "accuracy {}/{} ({:.1} %), mean efficiency {:.2} TOPS/W",
        correct,
        clips,
        correct as f64 / clips as f64 * 100.0,
        total_tops_w / clips as f64
    );
    Ok(())
}

fn cmd_flow(flags: &HashMap<String, String>) -> Result<()> {
    let wb: u32 = flag(flags, "wb", 4);
    let clips: usize = flag(flags, "clips", 4);
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".into());
    let p = Precision::from_weight_bits(wb)?;

    let mut store = ArtifactStore::open(&dir)?;
    let name = format!("flow_w{wb}");
    let mut golden = GoldenModel::new(&store, &name)?;
    let (_, h, w) = golden.frame_shape();
    let cfg = FlowSceneConfig {
        height: h,
        width: w,
        timesteps: golden.timesteps,
        ..Default::default()
    };

    let bundle = WeightBundle::load(store.swb_path("flow", wb))?;
    let net = flow_network(&bundle, p, h, w, golden.timesteps)?;
    let compiled = NetworkCompiler::compile(net, SimConfig::timing_only(p))?;

    let mut total_aee = 0.0;
    for i in 0..clips {
        let scene = make_flow_scene(9000 + i as u64, &cfg);
        golden.run_clip(&mut store, &scene.frames)?;
        let pred = golden.out_float();
        // out (M, 2) row-major -> u/v planes
        let m = h * w;
        let pred_u: Vec<f32> = (0..m).map(|j| pred[j * 2] as f32).collect();
        let pred_v: Vec<f32> = (0..m).map(|j| pred[j * 2 + 1] as f32).collect();
        let aee = average_endpoint_error(&scene, &pred_u, &pred_v);
        total_aee += aee;

        let mut state = compiled.network.init_state()?;
        let report = compiled.run_clip(&scene.frames, &mut state)?;
        println!(
            "clip {i}: AEE {:.3} px/step | {:.0} kcycles, {:.2} uJ, {:.2} TOPS/W",
            aee,
            report.total.cycles as f64 / 1e3,
            report.total.total_energy_pj(Corner::LOW) / 1e6,
            report.total.tops_per_watt(Corner::LOW),
        );
    }
    println!(
        "mean AEE {:.3} px/step over {clips} clips",
        total_aee / clips as f64
    );
    Ok(())
}

fn cmd_lint(flags: &HashMap<String, String>) -> Result<()> {
    let root = flags.get("root").map(|s| s.as_str()).unwrap_or(".");
    let report = spidr::lint::lint_tree(std::path::Path::new(root))?;
    for v in &report.violations {
        println!("{v}");
    }
    if report.violations.is_empty() {
        println!(
            "lint clean: {} files scanned (facade-only, wall-clock, total-decode, bench-emit)",
            report.files_scanned
        );
        Ok(())
    } else {
        Err(Error::config(format!(
            "lint: {} violation(s) across {} scanned files",
            report.violations.len(),
            report.files_scanned
        )))
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    let result = match cmd {
        "chip" => cmd_chip(&flags),
        "map" => cmd_map(&flags),
        "gesture" => cmd_gesture(&flags),
        "flow" => cmd_flow(&flags),
        "shard" => cmd_shard(&flags),
        "metrics" => cmd_metrics(&flags),
        "plan" => cmd_plan(&flags),
        "lint" => cmd_lint(&flags),
        _ => {
            eprintln!(
                "usage: spidr <chip|map|gesture|flow|shard|metrics|plan|lint> [--wb 4|6|8] \
                 [--sparsity S] [--corner low|high] [--task T] \
                 [--clips N] [--artifacts DIR] [--listen HOST:PORT] \
                 [--workload W] [--timesteps N] [--sessions N] [--protocol 2|3] \
                 [--trace FILE] [--metrics-listen HOST:PORT] [--connect HOST:PORT] \
                 [--links MBxUS,...] [--root DIR]"
            );
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
