//! The deterministic-interleaving runtime behind `cfg(spidr_model)`.
//!
//! Real OS threads are *serialized*: at every synchronization operation
//! a virtual thread parks, registers the operation it wants to perform
//! next ([`Op`]), and waits until the scheduler grants it the single
//! `active` slot. The scheduler picks among *enabled* operations; each
//! pick is one entry in the decision trail, and the explorer in
//! `mod.rs` backtracks over that trail (DFS with a preemption bound
//! and Mazurkiewicz-style state-hash pruning) to enumerate
//! interleavings exhaustively at small bounds.
//!
//! Nothing here is compiled into release builds — `crate::sync`
//! re-exports plain `std` primitives unless `--cfg spidr_model` is set.

use std::cell::RefCell;
use std::collections::HashSet;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};
use std::sync::PoisonError;

use super::{Config, Failure, FailureKind};

/// splitmix64 finalizer: the hash mixer for state fingerprints.
pub(crate) fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Index into the per-execution object table.
pub(crate) type ObjId = usize;

/// Why a thread is trying to (re-)acquire a mutex.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum AcquireWhy {
    /// Plain `Mutex::lock`.
    Lock,
    /// Re-acquire after a condvar notification.
    Notified,
    /// Re-acquire after a condvar timed wait fired its timeout.
    TimedOut,
}

/// The operation a parked virtual thread wants to perform next.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Op {
    /// Thread creation: the first scheduling point of a new vthread.
    Start,
    /// An always-enabled scheduling point (atomics, unlock, notify,
    /// sleep, explicit yield). The tag and optional object feed the
    /// trace and the state hash.
    Yield(&'static str, Option<ObjId>),
    /// Block until the mutex is free, then take it.
    Acquire {
        /// Target mutex.
        m: ObjId,
        /// What brought the thread here (trace + grant flavor).
        why: AcquireWhy,
    },
    /// Non-blocking lock attempt (always enabled; outcome in grant).
    TryLock {
        /// Target mutex.
        m: ObjId,
    },
    /// Atomically release `m` and wait on `cv`. Never enabled by
    /// itself: a notify converts it to `Acquire{why: Notified}`, and
    /// when `timed` the scheduler may fire the timeout instead.
    CvWait {
        /// Condvar waited on.
        cv: ObjId,
        /// Mutex released for the duration of the wait.
        m: ObjId,
        /// Whether this is `wait_timeout` (timeout may fire).
        timed: bool,
    },
    /// Blocking channel send.
    Send {
        /// Target channel.
        ch: ObjId,
    },
    /// Non-blocking channel send (always enabled; outcome in grant).
    TrySend {
        /// Target channel.
        ch: ObjId,
    },
    /// Blocking channel receive.
    Recv {
        /// Target channel.
        ch: ObjId,
        /// Whether this is `recv_timeout` (timeout may fire).
        timed: bool,
    },
    /// Non-blocking receive (always enabled; outcome in grant).
    TryRecv {
        /// Target channel.
        ch: ObjId,
    },
    /// Block until vthread `tid` has finished.
    Join {
        /// Joined vthread.
        tid: usize,
    },
}

/// What the scheduler decided for a granted [`Op`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Grant {
    /// Proceed (start/yield/acquire/join).
    Go,
    /// Condvar wait woke via notify and re-acquired the mutex.
    LockedNotified,
    /// Condvar timed wait fired its timeout and re-acquired the mutex.
    LockedTimedOut,
    /// `try_lock` succeeded.
    TryLockOk,
    /// `try_lock` would block.
    TryLockBusy,
    /// Blocking send accepted (buffer slot or rendezvous).
    SendOk,
    /// Blocking send failed: receiver dropped.
    SendDisconnected,
    /// `try_send` accepted.
    TrySendOk,
    /// `try_send` would block (buffer full / no rendezvous reader).
    TrySendFull,
    /// `try_send` failed: receiver dropped.
    TrySendDisconnected,
    /// Receive got a value.
    RecvData,
    /// Receive failed: every sender dropped and the buffer is empty.
    RecvDisconnected,
    /// `recv_timeout` fired its timeout.
    RecvTimedOut,
    /// `try_recv` got a value.
    TryRecvData,
    /// `try_recv` found the buffer empty.
    TryRecvEmpty,
    /// `try_recv` failed: every sender dropped and the buffer is empty.
    TryRecvDisconnected,
}

/// Immediate (non-blocking, but history-folded) state changes.
pub(crate) enum Effect {
    /// Release a mutex.
    Unlock(ObjId),
    /// Wake every waiter on a condvar.
    NotifyAll(ObjId),
    /// Wake the lowest-tid waiter (FIFO approximation; the repo only
    /// uses `notify_all`, this exists for completeness).
    NotifyOne(ObjId),
    /// A sender handle was cloned.
    SenderClone(ObjId),
    /// A sender handle was dropped.
    SenderDrop(ObjId),
    /// The receiver was dropped.
    ReceiverDrop(ObjId),
}

#[derive(Clone, Debug)]
enum Status {
    /// Parked at a scheduling point with a pending op.
    Ready(Op),
    /// Granted: currently running user code.
    Active,
    /// Body returned (or unwound); joinable.
    Finished,
}

struct VThread {
    status: Status,
    grant: Option<Grant>,
    /// Scheduling points taken so far (seeds object identities).
    ops: u64,
    name: String,
}

#[derive(Clone, Copy, Debug)]
pub(crate) enum ObjKind {
    /// A mutex; `locked` is the model-level ownership bit.
    Mutex {
        /// Whether some vthread holds it.
        locked: bool,
    },
    /// A condvar (waiters are tracked via thread statuses).
    Condvar,
    /// A channel endpoint pair.
    Chan {
        /// Values currently buffered.
        len: usize,
        /// `None` = unbounded, `Some(0)` = rendezvous.
        cap: Option<usize>,
        /// Live sender handles.
        senders: usize,
        /// Whether the receiver is still alive.
        recv_alive: bool,
    },
    /// An atomic cell (value history folded at op time).
    Atomic,
}

struct Obj {
    kind: ObjKind,
    /// Folded per-object operation history (Mazurkiewicz trace hash).
    hist: u64,
    /// Stable identity seed: mix(creator tid, creator op-count).
    seed: u64,
}

/// One scheduler decision in the trail.
struct Choice {
    n: usize,
    chosen: usize,
    /// Whether option 0 was "keep running the previous thread"
    /// (any other pick then costs one preemption).
    has_la: bool,
    preemptions_before: usize,
    desc: String,
}

struct State {
    threads: Vec<VThread>,
    objects: Vec<Obj>,
    active: Option<usize>,
    last_active: Option<usize>,
    trail: Vec<Choice>,
    prefix: Vec<usize>,
    cursor: usize,
    preemptions: usize,
    steps: usize,
    visited: HashSet<u64>,
    aborting: bool,
    pruned: bool,
    failure: Option<Failure>,
    /// OS threads (incl. vthread 0) that have not run `thread_end`.
    live_os: usize,
}

/// Monotone epoch distinguishing executions, so process-global
/// `ObjCell`s (obs statics) re-register lazily per execution.
static EPOCH: AtomicU64 = AtomicU64::new(1);

/// Lazily-registered object identity, packed `epoch << 32 | id + 1`.
/// `const`-constructible so `crate::sync` statics stay `const`.
pub(crate) struct ObjCell(AtomicU64);

impl ObjCell {
    /// An unregistered cell.
    pub(crate) const fn new() -> Self {
        ObjCell(AtomicU64::new(0))
    }
}

impl Default for ObjCell {
    fn default() -> Self {
        Self::new()
    }
}

/// Silent unwind payload used to tear threads down on abort.
pub(crate) struct Abort;

/// `model_assert!` failure payload.
pub(crate) struct ModelFailureMsg(pub String);

/// Per-OS-thread binding to the runtime of the current execution.
#[derive(Clone)]
pub(crate) struct Ctx {
    pub(crate) rt: Arc<Rt>,
    pub(crate) vtid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

/// The current model context, if this OS thread is a vthread.
pub(crate) fn ctx() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

/// The serialization runtime for one execution.
pub(crate) struct Rt {
    st: StdMutex<State>,
    cv: StdCondvar,
    epoch: u64,
    bound: usize,
    max_steps: usize,
    prune: bool,
}

fn unpoison<T>(r: Result<T, PoisonError<T>>) -> T {
    r.unwrap_or_else(PoisonError::into_inner)
}

impl Rt {
    /// A fresh execution: replay `prefix`, reuse `visited` across
    /// executions. `prune=false` disables state-hash pruning (replay).
    pub(crate) fn new(cfg: &Config, prefix: Vec<usize>, visited: HashSet<u64>, prune: bool) -> Rt {
        Rt {
            st: StdMutex::new(State {
                threads: vec![VThread {
                    status: Status::Ready(Op::Start),
                    grant: Some(Grant::Go),
                    ops: 0,
                    name: "main".to_string(),
                }],
                objects: Vec::new(),
                active: Some(0),
                last_active: None,
                trail: Vec::new(),
                prefix,
                cursor: 0,
                preemptions: 0,
                steps: 0,
                visited,
                aborting: false,
                pruned: false,
                failure: None,
                live_os: 1,
            }),
            cv: StdCondvar::new(),
            epoch: EPOCH.fetch_add(1, Ordering::Relaxed),
            bound: cfg.preemption_bound,
            max_steps: cfg.max_steps,
            prune,
        }
    }

    fn lock(&self) -> StdMutexGuard<'_, State> {
        unpoison(self.st.lock())
    }

    /// Register (or look up) the model object behind `cell`.
    pub(crate) fn obj_id(&self, cell: &ObjCell, kind: ObjKind, vtid: usize) -> ObjId {
        let mut st = self.lock();
        self.obj_id_locked(&mut st, cell, kind, vtid)
    }

    fn obj_id_locked(&self, st: &mut State, cell: &ObjCell, kind: ObjKind, vtid: usize) -> ObjId {
        let packed = cell.0.load(Ordering::Relaxed);
        if packed >> 32 == self.epoch && packed & 0xffff_ffff != 0 {
            return ((packed & 0xffff_ffff) - 1) as usize;
        }
        let id = st.objects.len();
        let seed = mix64(((vtid as u64) << 32) ^ st.threads[vtid].ops ^ (id as u64).rotate_left(17));
        st.objects.push(Obj { kind, hist: 0, seed });
        cell.0
            .store((self.epoch << 32) | (id as u64 + 1), Ordering::Relaxed);
        id
    }

    /// Park at a scheduling point and wait for the grant.
    /// Must not be called while unwinding (shims fall back instead).
    pub(crate) fn op(&self, vtid: usize, op: Op) -> Grant {
        let mut st = self.lock();
        if st.aborting {
            drop(st);
            std::panic::panic_any(Abort);
        }
        st.threads[vtid].ops += 1;
        st.threads[vtid].status = Status::Ready(op);
        st.threads[vtid].grant = None;
        if st.active == Some(vtid) {
            st.last_active = Some(vtid);
            st.active = None;
            self.schedule(&mut st);
        }
        loop {
            if st.aborting {
                drop(st);
                std::panic::panic_any(Abort);
            }
            if st.active == Some(vtid) {
                break;
            }
            st = unpoison(self.cv.wait(st));
        }
        st.threads[vtid].status = Status::Active;
        st.threads[vtid]
            .grant
            .take()
            .expect("granted vthread must carry a grant")
    }

    /// Atomically release `m` and park waiting on `cv`: the unlock
    /// effect and the wait registration happen with no scheduling
    /// point in between (real condvar atomicity — a notify can never
    /// slip into the release-to-park window).
    pub(crate) fn cv_wait(&self, vtid: usize, cv: ObjId, m: ObjId, timed: bool) -> Grant {
        {
            let mut st = self.lock();
            Self::apply_effect(&mut st, vtid, &Effect::Unlock(m));
        }
        self.op(vtid, Op::CvWait { cv, m, timed })
    }

    /// Apply an immediate effect, then take a yield scheduling point
    /// (skipped while unwinding: best-effort state update only).
    pub(crate) fn effect_then_yield(&self, vtid: usize, eff: Effect, tag: &'static str) {
        let obj = {
            let mut st = self.lock();
            Self::apply_effect(&mut st, vtid, &eff)
        };
        if !std::thread::panicking() {
            self.op(vtid, Op::Yield(tag, Some(obj)));
        }
    }

    /// Fold an observed value (atomic results) into an object history.
    pub(crate) fn fold_value(&self, obj: ObjId, v: u64) {
        let mut st = self.lock();
        st.objects[obj].hist = mix64(st.objects[obj].hist ^ v.rotate_left(7));
    }

    fn apply_effect(st: &mut State, vtid: usize, eff: &Effect) -> ObjId {
        let (obj, tag) = match *eff {
            Effect::Unlock(m) => {
                if let ObjKind::Mutex { ref mut locked } = st.objects[m].kind {
                    *locked = false;
                }
                (m, 1u64)
            }
            Effect::NotifyAll(cv) => {
                Self::notify(st, cv, usize::MAX);
                (cv, 2)
            }
            Effect::NotifyOne(cv) => {
                Self::notify(st, cv, 1);
                (cv, 3)
            }
            Effect::SenderClone(ch) => {
                if let ObjKind::Chan {
                    ref mut senders, ..
                } = st.objects[ch].kind
                {
                    *senders += 1;
                }
                (ch, 4)
            }
            Effect::SenderDrop(ch) => {
                if let ObjKind::Chan {
                    ref mut senders, ..
                } = st.objects[ch].kind
                {
                    *senders = senders.saturating_sub(1);
                }
                (ch, 5)
            }
            Effect::ReceiverDrop(ch) => {
                if let ObjKind::Chan {
                    ref mut recv_alive, ..
                } = st.objects[ch].kind
                {
                    *recv_alive = false;
                }
                (ch, 6)
            }
        };
        st.objects[obj].hist = mix64(st.objects[obj].hist ^ ((vtid as u64) << 40) ^ tag);
        obj
    }

    /// Convert up to `max` waiters on `cv` into mutex re-acquirers.
    fn notify(st: &mut State, cv: ObjId, max: usize) {
        let mut woken = 0;
        for t in st.threads.iter_mut() {
            if woken >= max {
                break;
            }
            if let Status::Ready(Op::CvWait { cv: c, m, .. }) = t.status {
                if c == cv {
                    t.status = Status::Ready(Op::Acquire {
                        m,
                        why: AcquireWhy::Notified,
                    });
                    woken += 1;
                }
            }
        }
    }

    /// Register a new vthread (called from the spawner, which is
    /// active); the OS thread attaches later via `thread_begin`.
    pub(crate) fn register_thread(&self, name: String) -> usize {
        let mut st = self.lock();
        st.live_os += 1;
        st.threads.push(VThread {
            status: Status::Ready(Op::Start),
            grant: None,
            ops: 0,
            name,
        });
        st.threads.len() - 1
    }

    fn thread_begin(&self, vtid: usize) {
        let mut st = self.lock();
        loop {
            if st.aborting {
                drop(st);
                std::panic::panic_any(Abort);
            }
            if st.active == Some(vtid) {
                break;
            }
            st = unpoison(self.cv.wait(st));
        }
        st.threads[vtid].status = Status::Active;
        st.threads[vtid].grant = None;
    }

    fn thread_end(&self, vtid: usize, payload: Option<Box<dyn std::any::Any + Send>>) {
        let mut st = self.lock();
        st.live_os -= 1;
        st.threads[vtid].status = Status::Finished;
        if let Some(p) = payload {
            if !st.aborting && !p.is::<Abort>() {
                let kind = match p.downcast::<ModelFailureMsg>() {
                    Ok(mf) => FailureKind::Assertion(mf.0),
                    Err(p) => FailureKind::Panic(panic_message(&p)),
                };
                self.fail(&mut st, kind);
            }
        }
        if st.active == Some(vtid) {
            st.last_active = Some(vtid);
            st.active = None;
            if !st.aborting {
                self.schedule(&mut st);
            }
        }
        self.cv.notify_all();
    }

    /// Mark a registered vthread whose OS thread never started (spawn
    /// failure) as finished so the execution can still complete.
    pub(crate) fn thread_end_external(&self, vtid: usize) {
        let mut st = self.lock();
        st.live_os -= 1;
        st.threads[vtid].status = Status::Finished;
        self.cv.notify_all();
    }

    /// Classify a panic payload caught mid-body (scope teardown) and
    /// abort the execution so parked threads unwind instead of
    /// wedging an implicit join.
    pub(crate) fn abort_with(&self, payload: Box<dyn std::any::Any + Send>) {
        let mut st = self.lock();
        if st.aborting {
            return;
        }
        if payload.is::<Abort>() {
            st.aborting = true;
            self.cv.notify_all();
            return;
        }
        let kind = match payload.downcast::<ModelFailureMsg>() {
            Ok(mf) => FailureKind::Assertion(mf.0),
            Err(p) => FailureKind::Panic(panic_message(&p)),
        };
        self.fail(&mut st, kind);
    }

    /// Block until every OS thread of this execution has detached.
    pub(crate) fn wait_quiescent(&self) {
        let mut st = self.lock();
        while st.live_os > 0 {
            st = unpoison(self.cv.wait(st));
        }
    }

    /// Harvest (trail schedule, pruned?, failure, visited set).
    pub(crate) fn take_outcome(&self) -> (Vec<(usize, usize, bool, usize)>, bool, Option<Failure>, HashSet<u64>) {
        let mut st = self.lock();
        let trail = st
            .trail
            .iter()
            .map(|c| (c.n, c.chosen, c.has_la, c.preemptions_before))
            .collect();
        let visited = std::mem::take(&mut st.visited);
        (trail, st.pruned, st.failure.take(), visited)
    }

    fn fail(&self, st: &mut State, kind: FailureKind) {
        if st.failure.is_none() {
            let schedule: Vec<usize> = st.trail.iter().map(|c| c.chosen).collect();
            let mut trace: String = st
                .trail
                .iter()
                .map(|c| c.desc.as_str())
                .collect::<Vec<_>>()
                .join("\n");
            trace.push_str("\nfinal thread states:");
            for (tid, t) in st.threads.iter().enumerate() {
                trace.push_str(&format!("\n  t{tid}<{}> {:?}", t.name, t.status));
            }
            st.failure = Some(Failure {
                kind,
                schedule,
                trace,
            });
        }
        st.aborting = true;
        self.cv.notify_all();
    }

    fn enabled(st: &State, op: &Op) -> bool {
        match *op {
            Op::Start | Op::Yield(..) | Op::TryLock { .. } | Op::TrySend { .. } | Op::TryRecv { .. } => true,
            Op::Acquire { m, .. } => matches!(st.objects[m].kind, ObjKind::Mutex { locked: false }),
            Op::CvWait { .. } => false,
            Op::Send { ch } => match st.objects[ch].kind {
                ObjKind::Chan {
                    len,
                    cap,
                    recv_alive,
                    ..
                } => {
                    if !recv_alive {
                        return true; // grant = SendDisconnected
                    }
                    match cap {
                        None => true,
                        Some(0) => len == 0 && Self::recv_parked(st, ch),
                        Some(c) => len < c,
                    }
                }
                _ => false,
            },
            Op::Recv { ch, .. } => match st.objects[ch].kind {
                ObjKind::Chan { len, senders, .. } => len > 0 || senders == 0,
                _ => false,
            },
            Op::Join { tid } => matches!(st.threads[tid].status, Status::Finished),
        }
    }

    fn recv_parked(st: &State, ch: ObjId) -> bool {
        st.threads.iter().any(|t| {
            matches!(t.status, Status::Ready(Op::Recv { ch: c, .. }) if c == ch)
        })
    }

    /// Pick the next vthread to run. Called with the state locked and
    /// `active == None`; loops because a fired condvar timeout leaves
    /// its thread blocked on mutex re-acquisition.
    fn schedule(&self, st: &mut State) {
        loop {
            if st.aborting {
                self.cv.notify_all();
                return;
            }
            st.steps += 1;
            if st.steps > self.max_steps {
                self.fail(st, FailureKind::StepLimit);
                return;
            }
            // Candidates: enabled ops first (previous thread in front
            // so option 0 never costs a preemption), then timeout
            // firings of timed waiters, by tid.
            let mut normal: Vec<usize> = Vec::new();
            let mut fires: Vec<usize> = Vec::new();
            for (tid, t) in st.threads.iter().enumerate() {
                if let Status::Ready(op) = &t.status {
                    if Self::enabled(st, op) {
                        normal.push(tid);
                    }
                    if matches!(
                        op,
                        Op::CvWait { timed: true, .. } | Op::Recv { timed: true, .. }
                    ) {
                        fires.push(tid);
                    }
                }
            }
            let mut has_la = false;
            if let Some(la) = st.last_active {
                if let Some(pos) = normal.iter().position(|&t| t == la) {
                    normal.remove(pos);
                    normal.insert(0, la);
                    has_la = true;
                }
            }
            let n_normal = normal.len();
            normal.extend(fires.iter().copied());
            let cands = normal;
            if cands.is_empty() {
                if st.threads.iter().all(|t| matches!(t.status, Status::Finished)) {
                    self.cv.notify_all();
                    return;
                }
                let lost = st.threads.iter().all(|t| {
                    matches!(
                        t.status,
                        Status::Finished | Status::Ready(Op::CvWait { timed: false, .. })
                    )
                });
                self.fail(
                    st,
                    if lost {
                        FailureKind::LostWakeup
                    } else {
                        FailureKind::Deadlock
                    },
                );
                return;
            }
            let idx = if st.cursor < st.prefix.len() {
                st.prefix[st.cursor].min(cands.len() - 1)
            } else {
                0
            };
            let cost = usize::from(has_la && idx > 0);
            let preemptions_before = st.preemptions;
            st.preemptions += cost;
            let tid = cands[idx];
            let fire = idx >= n_normal;
            let desc = {
                let t = &st.threads[tid];
                let what = match (&t.status, fire) {
                    (Status::Ready(op), false) => format!("{op:?}"),
                    (Status::Ready(op), true) => format!("timeout-fire {op:?}"),
                    _ => "?".to_string(),
                };
                format!(
                    "[{:>3}] t{tid}<{}> {what} ({} of {} candidates)",
                    st.trail.len(),
                    t.name,
                    idx + 1,
                    cands.len()
                )
            };
            st.trail.push(Choice {
                n: cands.len(),
                chosen: idx,
                has_la,
                preemptions_before,
                desc,
            });
            st.cursor += 1;
            if fire {
                self.fire_timeout(st, tid);
            } else {
                self.apply_grant(st, tid);
            }
            if st.active.is_some() {
                if self.prune && st.cursor > st.prefix.len() {
                    let h = Self::state_hash(st);
                    if !st.visited.insert(h) {
                        st.pruned = true;
                        st.aborting = true;
                    }
                }
                self.cv.notify_all();
                return;
            }
        }
    }

    fn fire_timeout(&self, st: &mut State, tid: usize) {
        match st.threads[tid].status {
            Status::Ready(Op::CvWait { m, .. }) => {
                st.threads[tid].status = Status::Ready(Op::Acquire {
                    m,
                    why: AcquireWhy::TimedOut,
                });
                // Not granted yet: the thread still has to win the
                // mutex back; the scheduler loop re-picks.
            }
            Status::Ready(Op::Recv { ch, .. }) => {
                st.objects[ch].hist = mix64(st.objects[ch].hist ^ ((tid as u64) << 40) ^ 0x7e);
                st.threads[tid].grant = Some(Grant::RecvTimedOut);
                st.active = Some(tid);
            }
            _ => unreachable!("timeout fired for a non-timed op"),
        }
    }

    fn apply_grant(&self, st: &mut State, tid: usize) {
        let op = match &st.threads[tid].status {
            Status::Ready(op) => *op,
            _ => unreachable!("granting a non-ready thread"),
        };
        let (grant, touched) = match op {
            Op::Start => (Grant::Go, None),
            Op::Yield(_, obj) => (Grant::Go, obj),
            Op::Join { .. } => (Grant::Go, None),
            Op::Acquire { m, why } => {
                if let ObjKind::Mutex { ref mut locked } = st.objects[m].kind {
                    *locked = true;
                }
                let g = match why {
                    AcquireWhy::Lock => Grant::Go,
                    AcquireWhy::Notified => Grant::LockedNotified,
                    AcquireWhy::TimedOut => Grant::LockedTimedOut,
                };
                (g, Some(m))
            }
            Op::TryLock { m } => {
                if let ObjKind::Mutex { ref mut locked } = st.objects[m].kind {
                    if *locked {
                        (Grant::TryLockBusy, Some(m))
                    } else {
                        *locked = true;
                        (Grant::TryLockOk, Some(m))
                    }
                } else {
                    unreachable!("try_lock on a non-mutex")
                }
            }
            Op::Send { ch } => {
                if let ObjKind::Chan {
                    ref mut len,
                    recv_alive,
                    ..
                } = st.objects[ch].kind
                {
                    if recv_alive {
                        *len += 1;
                        (Grant::SendOk, Some(ch))
                    } else {
                        (Grant::SendDisconnected, Some(ch))
                    }
                } else {
                    unreachable!("send on a non-channel")
                }
            }
            Op::TrySend { ch } => {
                let parked = Self::recv_parked(st, ch);
                if let ObjKind::Chan {
                    ref mut len,
                    cap,
                    recv_alive,
                    ..
                } = st.objects[ch].kind
                {
                    if !recv_alive {
                        (Grant::TrySendDisconnected, Some(ch))
                    } else {
                        let room = match cap {
                            None => true,
                            Some(0) => *len == 0 && parked,
                            Some(c) => *len < c,
                        };
                        if room {
                            *len += 1;
                            (Grant::TrySendOk, Some(ch))
                        } else {
                            (Grant::TrySendFull, Some(ch))
                        }
                    }
                } else {
                    unreachable!("try_send on a non-channel")
                }
            }
            Op::Recv { ch, .. } => {
                if let ObjKind::Chan { ref mut len, .. } = st.objects[ch].kind {
                    if *len > 0 {
                        *len -= 1;
                        (Grant::RecvData, Some(ch))
                    } else {
                        (Grant::RecvDisconnected, Some(ch))
                    }
                } else {
                    unreachable!("recv on a non-channel")
                }
            }
            Op::TryRecv { ch } => {
                if let ObjKind::Chan {
                    ref mut len,
                    senders,
                    ..
                } = st.objects[ch].kind
                {
                    if *len > 0 {
                        *len -= 1;
                        (Grant::TryRecvData, Some(ch))
                    } else if senders == 0 {
                        (Grant::TryRecvDisconnected, Some(ch))
                    } else {
                        (Grant::TryRecvEmpty, Some(ch))
                    }
                } else {
                    unreachable!("try_recv on a non-channel")
                }
            }
            Op::CvWait { .. } => unreachable!("cv wait is never directly enabled"),
        };
        if let Some(obj) = touched {
            st.objects[obj].hist =
                mix64(st.objects[obj].hist ^ ((tid as u64) << 40) ^ grant_tag(grant));
        }
        st.threads[tid].grant = Some(grant);
        st.active = Some(tid);
    }

    /// Fingerprint of the current abstract state. Two interleavings
    /// that produce identical per-object operation histories (i.e.
    /// differ only in the order of operations on *different* objects —
    /// Mazurkiewicz trace equivalence) collide on purpose and the
    /// second is pruned. Sound up to 64-bit hash collisions; the
    /// preemption budget already spent is folded in so a state first
    /// seen with less remaining budget cannot mask a richer revisit.
    fn state_hash(st: &State) -> u64 {
        let mut h = mix64(st.preemptions as u64 ^ 0xa5a5);
        for o in &st.objects {
            let sub = match o.kind {
                ObjKind::Mutex { locked } => u64::from(locked),
                ObjKind::Condvar => 2,
                ObjKind::Chan {
                    len,
                    senders,
                    recv_alive,
                    ..
                } => 4 ^ ((len as u64) << 2) ^ ((senders as u64) << 20) ^ (u64::from(recv_alive) << 40),
                ObjKind::Atomic => 8,
            };
            h ^= mix64(o.seed ^ o.hist ^ sub.rotate_left(13));
        }
        for (tid, t) in st.threads.iter().enumerate() {
            let s = match &t.status {
                Status::Ready(op) => mix64(0x11 ^ op_tag(op)),
                Status::Active => 0x22,
                Status::Finished => 0x33,
            };
            h ^= mix64(((tid as u64) << 48) ^ s);
        }
        h
    }

    /// Next DFS prefix: the deepest choice with an untried alternative
    /// that fits the preemption bound, or `None` when the bounded
    /// space is exhausted. Trail entries are `(n, chosen, has_la,
    /// preemptions_before)` as returned by [`Rt::take_outcome`].
    pub(crate) fn next_prefix(
        trail: &[(usize, usize, bool, usize)],
        bound: usize,
    ) -> Option<Vec<usize>> {
        for i in (0..trail.len()).rev() {
            let (n, chosen, has_la, before) = trail[i];
            let j = chosen + 1;
            if j < n {
                let cost = usize::from(has_la && j > 0);
                if before + cost <= bound {
                    let mut p: Vec<usize> = trail[..i].iter().map(|c| c.1).collect();
                    p.push(j);
                    return Some(p);
                }
            }
        }
        None
    }
}

fn op_tag(op: &Op) -> u64 {
    match op {
        Op::Start => 1,
        Op::Yield(..) => 2,
        Op::Acquire { m, why } => 3 ^ ((*m as u64) << 8) ^ ((*why as u64) << 4),
        Op::TryLock { m } => 4 ^ ((*m as u64) << 8),
        Op::CvWait { cv, m, timed } => {
            5 ^ ((*cv as u64) << 8) ^ ((*m as u64) << 24) ^ (u64::from(*timed) << 4)
        }
        Op::Send { ch } => 6 ^ ((*ch as u64) << 8),
        Op::TrySend { ch } => 7 ^ ((*ch as u64) << 8),
        Op::Recv { ch, timed } => 8 ^ ((*ch as u64) << 8) ^ (u64::from(*timed) << 4),
        Op::TryRecv { ch } => 9 ^ ((*ch as u64) << 8),
        Op::Join { tid } => 10 ^ ((*tid as u64) << 8),
    }
}

fn grant_tag(g: Grant) -> u64 {
    g as u64 + 0x40
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `f` as vthread `vtid` on the current OS thread: bind the TLS
/// context, take the first grant, and detach on the way out. Unwinds
/// with the silent [`Abort`] sentinel if the body panicked (the real
/// payload is classified into the execution's failure first).
pub(crate) fn run_vthread<T>(rt: &Arc<Rt>, vtid: usize, f: impl FnOnce() -> T) -> T {
    CTX.with(|c| {
        *c.borrow_mut() = Some(Ctx {
            rt: Arc::clone(rt),
            vtid,
        })
    });
    let res = catch_unwind(AssertUnwindSafe(|| {
        rt.thread_begin(vtid);
        f()
    }));
    CTX.with(|c| *c.borrow_mut() = None);
    match res {
        Ok(v) => {
            rt.thread_end(vtid, None);
            v
        }
        Err(p) => {
            rt.thread_end(vtid, Some(p));
            resume_unwind(Box::new(Abort))
        }
    }
}
