//! Model-checked drop-ins for `std::sync` primitives (`Mutex`,
//! `Condvar`, atomics). Compiled only under `cfg(spidr_model)`;
//! `crate::sync` re-exports these so production code is source- and
//! release-binary-identical to plain `std`.
//!
//! Outside an [`explore`](super::explore) run (no model context on
//! the current OS thread) every operation falls through to the real
//! `std` primitive, so `cfg(spidr_model)` builds still execute
//! non-model code correctly. While a model execution is *unwinding*
//! (abort teardown) operations become non-blocking best-effort so
//! drop guards can never wedge the scheduler.

use std::sync::{Condvar as StdCondvar, LockResult, Mutex as StdMutex, MutexGuard as StdMutexGuard};
use std::sync::PoisonError;
use std::time::Duration;

use super::rt::{self, AcquireWhy, Effect, Grant, ObjKind, Op};

fn unpoison<T>(r: Result<T, PoisonError<T>>) -> T {
    r.unwrap_or_else(PoisonError::into_inner)
}

/// A mutex whose acquire/release are scheduling points under the
/// model; plain `std::sync::Mutex` semantics otherwise.
pub struct Mutex<T: ?Sized> {
    cell: rt::ObjCell,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    /// Create a new mutex (usable in `static` initializers).
    pub const fn new(t: T) -> Mutex<T> {
        Mutex {
            cell: rt::ObjCell::new(),
            inner: StdMutex::new(t),
        }
    }

    /// Consume the mutex, returning the inner value.
    pub fn into_inner(self) -> LockResult<T> {
        Ok(unpoison(self.inner.into_inner()))
    }
}

impl<T: ?Sized> Mutex<T> {
    fn obj(&self, cx: &rt::Ctx) -> rt::ObjId {
        cx.rt
            .obj_id(&self.cell, ObjKind::Mutex { locked: false }, cx.vtid)
    }

    /// Acquire the lock, blocking the virtual thread. Never returns
    /// `Err`: model executions tear down via unwinding, and poisoned
    /// inner state from an aborted execution is deliberately ignored.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match rt::ctx() {
            Some(cx) if !std::thread::panicking() => {
                let m = self.obj(&cx);
                cx.rt.op(cx.vtid, Op::Acquire {
                    m,
                    why: AcquireWhy::Lock,
                });
                Ok(MutexGuard {
                    lock: self,
                    inner: Some(unpoison(self.inner.lock())),
                    modeled: true,
                })
            }
            _ => Ok(MutexGuard {
                lock: self,
                inner: Some(unpoison(self.inner.lock())),
                modeled: false,
            }),
        }
    }

    /// Attempt the lock without blocking (a scheduling point whose
    /// outcome the scheduler decides from the model state).
    pub fn try_lock(&self) -> Result<MutexGuard<'_, T>, std::sync::TryLockError<MutexGuard<'_, T>>> {
        match rt::ctx() {
            Some(cx) if !std::thread::panicking() => {
                let m = self.obj(&cx);
                match cx.rt.op(cx.vtid, Op::TryLock { m }) {
                    Grant::TryLockOk => Ok(MutexGuard {
                        lock: self,
                        inner: Some(unpoison(self.inner.lock())),
                        modeled: true,
                    }),
                    _ => Err(std::sync::TryLockError::WouldBlock),
                }
            }
            _ => match self.inner.try_lock() {
                Ok(g) => Ok(MutexGuard {
                    lock: self,
                    inner: Some(g),
                    modeled: false,
                }),
                Err(_) => Err(std::sync::TryLockError::WouldBlock),
            },
        }
    }

    /// Mutable access without locking (requires `&mut self`).
    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        Ok(unpoison(self.inner.get_mut()))
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

impl<T: ?Sized + std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mutex").finish_non_exhaustive()
    }
}

/// RAII guard for [`Mutex`]; releasing is a scheduling point.
pub struct MutexGuard<'a, T: ?Sized> {
    lock: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
    modeled: bool,
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard holds the lock")
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard holds the lock")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the OS-level lock first, then the model-level one.
        self.inner.take();
        if self.modeled {
            if let Some(cx) = rt::ctx() {
                let m = self.lock.obj(&cx);
                cx.rt.effect_then_yield(cx.vtid, Effect::Unlock(m), "unlock");
            }
        }
    }
}

/// Whether a [`Condvar::wait_timeout`] returned because of its
/// timeout. Mirrors `std::sync::WaitTimeoutResult`, which cannot be
/// constructed outside `std`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WaitTimeoutResult {
    timed_out: bool,
}

impl WaitTimeoutResult {
    /// `true` if the wait ended by timeout rather than a notify.
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }
}

/// A condition variable whose wait/notify are scheduling points under
/// the model. A timed wait's timeout is modeled as a nondeterministic
/// transition: the scheduler may fire it at any point, which is
/// exactly how timeout-vs-notify races get explored.
pub struct Condvar {
    cell: rt::ObjCell,
    inner: StdCondvar,
}

impl Condvar {
    /// Create a new condvar (usable in `static` initializers).
    pub const fn new() -> Condvar {
        Condvar {
            cell: rt::ObjCell::new(),
            inner: StdCondvar::new(),
        }
    }

    fn obj(&self, cx: &rt::Ctx) -> rt::ObjId {
        cx.rt.obj_id(&self.cell, ObjKind::Condvar, cx.vtid)
    }

    fn wait_model<'a, T: ?Sized>(
        &self,
        cx: &rt::Ctx,
        guard: MutexGuard<'a, T>,
        timed: bool,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        let lock = guard.lock;
        let cv = self.obj(cx);
        let m = lock.obj(cx);
        // Release the OS lock and defuse the guard so its Drop does
        // not double-release at the model level: the release below is
        // fused with the wait registration inside `cv_wait`.
        let mut guard = guard;
        guard.inner.take();
        std::mem::forget(guard);
        let grant = cx.rt.cv_wait(cx.vtid, cv, m, timed);
        let timed_out = grant == Grant::LockedTimedOut;
        (
            MutexGuard {
                lock,
                inner: Some(unpoison(lock.inner.lock())),
                modeled: true,
            },
            WaitTimeoutResult { timed_out },
        )
    }

    /// Release the guard's mutex and wait for a notification.
    pub fn wait<'a, T: ?Sized>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match rt::ctx() {
            Some(cx) if guard.modeled && !std::thread::panicking() => {
                Ok(self.wait_model(&cx, guard, false).0)
            }
            Some(_) => Ok(guard), // unwinding: never block teardown
            None => {
                let lock = guard.lock;
                let mut guard = guard;
                let std_guard = guard.inner.take().expect("guard holds the lock");
                std::mem::forget(guard);
                let g = unpoison(self.inner.wait(std_guard));
                Ok(MutexGuard {
                    lock,
                    inner: Some(g),
                    modeled: false,
                })
            }
        }
    }

    /// Like [`Condvar::wait`] but with a timeout the scheduler may
    /// fire at any point (the `Duration` value itself is ignored —
    /// model time is schedule order, not wall time).
    pub fn wait_timeout<'a, T: ?Sized>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match rt::ctx() {
            Some(cx) if guard.modeled && !std::thread::panicking() => {
                Ok(self.wait_model(&cx, guard, true))
            }
            Some(_) => Ok((guard, WaitTimeoutResult { timed_out: true })),
            None => {
                let lock = guard.lock;
                let mut guard = guard;
                let std_guard = guard.inner.take().expect("guard holds the lock");
                std::mem::forget(guard);
                let (g, res) = unpoison(self.inner.wait_timeout(std_guard, dur));
                Ok((
                    MutexGuard {
                        lock,
                        inner: Some(g),
                        modeled: false,
                    },
                    WaitTimeoutResult {
                        timed_out: res.timed_out(),
                    },
                ))
            }
        }
    }

    /// Wake every waiter.
    pub fn notify_all(&self) {
        self.inner.notify_all();
        if let Some(cx) = rt::ctx() {
            let cv = self.obj(&cx);
            cx.rt
                .effect_then_yield(cx.vtid, Effect::NotifyAll(cv), "notify_all");
        }
    }

    /// Wake one waiter (lowest virtual-thread id first — a FIFO
    /// approximation; the repo's protocols only use `notify_all`).
    pub fn notify_one(&self) {
        self.inner.notify_one();
        if let Some(cx) = rt::ctx() {
            let cv = self.obj(&cx);
            cx.rt
                .effect_then_yield(cx.vtid, Effect::NotifyOne(cv), "notify_one");
        }
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

/// Model-checked atomics: every operation is sequentially consistent
/// regardless of the requested `Ordering` (the model explores thread
/// interleavings, not hardware memory-order weakenings) and is a
/// scheduling point with the observed value folded into the state
/// hash.
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    use super::super::rt::{self, ObjKind, Op};
    use std::sync::atomic::Ordering::SeqCst;

    macro_rules! point {
        ($self:ident) => {
            match rt::ctx() {
                Some(cx) if !std::thread::panicking() => {
                    let obj = cx.rt.obj_id(&$self.cell, ObjKind::Atomic, cx.vtid);
                    cx.rt.op(cx.vtid, Op::Yield("atomic", Some(obj)));
                    Some((cx, obj))
                }
                _ => None,
            }
        };
    }

    macro_rules! fold {
        ($cx:expr, $v:expr) => {
            if let Some((cx, obj)) = &$cx {
                cx.rt.fold_value(*obj, $v as u64);
            }
        };
    }

    macro_rules! int_atomic {
        ($(#[$doc:meta])* $name:ident, $std:ident, $prim:ty) => {
            $(#[$doc])*
            pub struct $name {
                cell: rt::ObjCell,
                inner: std::sync::atomic::$std,
            }

            impl $name {
                /// Create a new atomic (usable in `static` initializers).
                pub const fn new(v: $prim) -> Self {
                    Self {
                        cell: rt::ObjCell::new(),
                        inner: std::sync::atomic::$std::new(v),
                    }
                }

                /// Load the value (SeqCst under the model).
                pub fn load(&self, _o: Ordering) -> $prim {
                    let p = point!(self);
                    let v = self.inner.load(SeqCst);
                    fold!(p, v);
                    v
                }

                /// Store a value (SeqCst under the model).
                pub fn store(&self, v: $prim, _o: Ordering) {
                    let p = point!(self);
                    self.inner.store(v, SeqCst);
                    fold!(p, v);
                }

                /// Swap in a value, returning the previous one.
                pub fn swap(&self, v: $prim, _o: Ordering) -> $prim {
                    let p = point!(self);
                    let old = self.inner.swap(v, SeqCst);
                    fold!(p, old);
                    old
                }

                /// Add, returning the previous value.
                pub fn fetch_add(&self, v: $prim, _o: Ordering) -> $prim {
                    let p = point!(self);
                    let old = self.inner.fetch_add(v, SeqCst);
                    fold!(p, old);
                    old
                }

                /// Subtract, returning the previous value.
                pub fn fetch_sub(&self, v: $prim, _o: Ordering) -> $prim {
                    let p = point!(self);
                    let old = self.inner.fetch_sub(v, SeqCst);
                    fold!(p, old);
                    old
                }

                /// Bitwise-or, returning the previous value.
                pub fn fetch_or(&self, v: $prim, _o: Ordering) -> $prim {
                    let p = point!(self);
                    let old = self.inner.fetch_or(v, SeqCst);
                    fold!(p, old);
                    old
                }

                /// Maximum, returning the previous value.
                pub fn fetch_max(&self, v: $prim, _o: Ordering) -> $prim {
                    let p = point!(self);
                    let old = self.inner.fetch_max(v, SeqCst);
                    fold!(p, old);
                    old
                }

                /// Compare-and-exchange (both orderings collapse to SeqCst).
                pub fn compare_exchange(
                    &self,
                    cur: $prim,
                    new: $prim,
                    _s: Ordering,
                    _f: Ordering,
                ) -> Result<$prim, $prim> {
                    let p = point!(self);
                    let r = self.inner.compare_exchange(cur, new, SeqCst, SeqCst);
                    match r {
                        Ok(v) | Err(v) => fold!(p, v),
                    }
                    r
                }
            }

            impl Default for $name {
                fn default() -> Self {
                    Self::new(Default::default())
                }
            }

            impl std::fmt::Debug for $name {
                fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                    self.inner.fmt(f)
                }
            }
        };
    }

    int_atomic!(
        /// Model-checked `AtomicUsize`.
        AtomicUsize,
        AtomicUsize,
        usize
    );
    int_atomic!(
        /// Model-checked `AtomicU32`.
        AtomicU32,
        AtomicU32,
        u32
    );
    int_atomic!(
        /// Model-checked `AtomicU64`.
        AtomicU64,
        AtomicU64,
        u64
    );
    int_atomic!(
        /// Model-checked `AtomicI64`.
        AtomicI64,
        AtomicI64,
        i64
    );

    /// Model-checked `AtomicBool`.
    pub struct AtomicBool {
        cell: rt::ObjCell,
        inner: std::sync::atomic::AtomicBool,
    }

    impl AtomicBool {
        /// Create a new atomic bool (usable in `static` initializers).
        pub const fn new(v: bool) -> Self {
            Self {
                cell: rt::ObjCell::new(),
                inner: std::sync::atomic::AtomicBool::new(v),
            }
        }

        /// Load the value (SeqCst under the model).
        pub fn load(&self, _o: Ordering) -> bool {
            let p = point!(self);
            let v = self.inner.load(SeqCst);
            fold!(p, v);
            v
        }

        /// Store a value (SeqCst under the model).
        pub fn store(&self, v: bool, _o: Ordering) {
            let p = point!(self);
            self.inner.store(v, SeqCst);
            fold!(p, v);
        }

        /// Swap in a value, returning the previous one.
        pub fn swap(&self, v: bool, _o: Ordering) -> bool {
            let p = point!(self);
            let old = self.inner.swap(v, SeqCst);
            fold!(p, old);
            old
        }

        /// Compare-and-exchange (both orderings collapse to SeqCst).
        pub fn compare_exchange(
            &self,
            cur: bool,
            new: bool,
            _s: Ordering,
            _f: Ordering,
        ) -> Result<bool, bool> {
            let p = point!(self);
            let r = self.inner.compare_exchange(cur, new, SeqCst, SeqCst);
            match r {
                Ok(v) | Err(v) => fold!(p, v),
            }
            r
        }
    }

    impl Default for AtomicBool {
        fn default() -> Self {
            Self::new(false)
        }
    }

    impl std::fmt::Debug for AtomicBool {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            self.inner.fmt(f)
        }
    }
}
