//! Model-checked `mpsc` channels: `channel` (unbounded) and
//! `sync_channel` (bounded / rendezvous) with the `std::sync::mpsc`
//! API surface the repo uses. Error types are re-exported from `std`
//! (they are publicly constructible). Compiled only under
//! `cfg(spidr_model)`.
//!
//! Send / recv / try-variants are scheduling points; buffered values
//! live in a plain `VecDeque` whose occupancy mirrors the scheduler's
//! abstract channel state. Outside a model execution the blocking
//! operations degrade to non-blocking best-effort (model channels are
//! only meaningful inside [`explore`](super::explore); the release
//! facade re-exports real `std::sync::mpsc` instead).
//!
//! One deliberate approximation: a rendezvous (`sync_channel(0)`)
//! send completes as soon as a blocked receiver is present, without
//! additionally blocking the sender until the value is taken. The
//! repo's protocols all use capacities ≥ 1.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex as StdMutex, PoisonError};
use std::time::Duration;

pub use std::sync::mpsc::{
    RecvError, RecvTimeoutError, SendError, TryRecvError, TrySendError,
};

use super::rt::{self, Effect, Grant, ObjKind, Op};

struct ChanInner<T> {
    cell: rt::ObjCell,
    cap: Option<usize>,
    buf: StdMutex<VecDeque<T>>,
}

impl<T> ChanInner<T> {
    fn obj(&self, cx: &rt::Ctx) -> rt::ObjId {
        cx.rt.obj_id(
            &self.cell,
            ObjKind::Chan {
                len: 0,
                cap: self.cap,
                senders: 1,
                recv_alive: true,
            },
            cx.vtid,
        )
    }

    fn push(&self, t: T) {
        self.buf
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push_back(t);
    }

    fn pop(&self) -> Option<T> {
        self.buf
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .pop_front()
    }
}

/// Create an unbounded model channel (`std::sync::mpsc::channel`).
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let inner = Arc::new(ChanInner {
        cell: rt::ObjCell::new(),
        cap: None,
        buf: StdMutex::new(VecDeque::new()),
    });
    if let Some(cx) = rt::ctx() {
        inner.obj(&cx); // register eagerly so handle counts start now
    }
    (
        Sender {
            ch: Arc::clone(&inner),
        },
        Receiver { ch: inner },
    )
}

/// Create a bounded model channel (`std::sync::mpsc::sync_channel`).
pub fn sync_channel<T>(cap: usize) -> (SyncSender<T>, Receiver<T>) {
    let inner = Arc::new(ChanInner {
        cell: rt::ObjCell::new(),
        cap: Some(cap),
        buf: StdMutex::new(VecDeque::new()),
    });
    if let Some(cx) = rt::ctx() {
        inner.obj(&cx);
    }
    (
        SyncSender {
            ch: Arc::clone(&inner),
        },
        Receiver { ch: inner },
    )
}

fn send_impl<T>(ch: &ChanInner<T>, t: T) -> Result<(), SendError<T>> {
    match rt::ctx() {
        Some(cx) if !std::thread::panicking() => {
            let obj = ch.obj(&cx);
            match cx.rt.op(cx.vtid, Op::Send { ch: obj }) {
                Grant::SendOk => {
                    ch.push(t);
                    Ok(())
                }
                _ => Err(SendError(t)),
            }
        }
        _ => {
            ch.push(t);
            Ok(())
        }
    }
}

fn clone_handle<T>(ch: &Arc<ChanInner<T>>) -> Arc<ChanInner<T>> {
    if let Some(cx) = rt::ctx() {
        let obj = ch.obj(&cx);
        cx.rt
            .effect_then_yield(cx.vtid, Effect::SenderClone(obj), "sender_clone");
    }
    Arc::clone(ch)
}

fn drop_sender<T>(ch: &ChanInner<T>) {
    if let Some(cx) = rt::ctx() {
        let obj = ch.obj(&cx);
        cx.rt
            .effect_then_yield(cx.vtid, Effect::SenderDrop(obj), "sender_drop");
    }
}

/// Sending half of an unbounded channel.
pub struct Sender<T> {
    ch: Arc<ChanInner<T>>,
}

impl<T> Sender<T> {
    /// Send a value; `Err` only if the receiver was dropped.
    pub fn send(&self, t: T) -> Result<(), SendError<T>> {
        send_impl(&self.ch, t)
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Sender {
            ch: clone_handle(&self.ch),
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        drop_sender(&self.ch);
    }
}

/// Sending half of a bounded channel.
pub struct SyncSender<T> {
    ch: Arc<ChanInner<T>>,
}

impl<T> SyncSender<T> {
    /// Send, blocking (a scheduling point) while the buffer is full.
    pub fn send(&self, t: T) -> Result<(), SendError<T>> {
        send_impl(&self.ch, t)
    }

    /// Non-blocking send; the scheduler decides the outcome from the
    /// model channel state.
    pub fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
        match rt::ctx() {
            Some(cx) if !std::thread::panicking() => {
                let obj = self.ch.obj(&cx);
                match cx.rt.op(cx.vtid, Op::TrySend { ch: obj }) {
                    Grant::TrySendOk => {
                        self.ch.push(t);
                        Ok(())
                    }
                    Grant::TrySendFull => Err(TrySendError::Full(t)),
                    _ => Err(TrySendError::Disconnected(t)),
                }
            }
            _ => {
                self.ch.push(t);
                Ok(())
            }
        }
    }
}

impl<T> Clone for SyncSender<T> {
    fn clone(&self) -> Self {
        SyncSender {
            ch: clone_handle(&self.ch),
        }
    }
}

impl<T> Drop for SyncSender<T> {
    fn drop(&mut self) {
        drop_sender(&self.ch);
    }
}

/// Receiving half of a model channel.
pub struct Receiver<T> {
    ch: Arc<ChanInner<T>>,
}

impl<T> Receiver<T> {
    /// Receive, blocking (a scheduling point) while the buffer is
    /// empty and senders remain.
    pub fn recv(&self) -> Result<T, RecvError> {
        match rt::ctx() {
            Some(cx) if !std::thread::panicking() => {
                let obj = self.ch.obj(&cx);
                match cx.rt.op(cx.vtid, Op::Recv {
                    ch: obj,
                    timed: false,
                }) {
                    Grant::RecvData => Ok(self.ch.pop().expect("granted recv finds a value")),
                    _ => Err(RecvError),
                }
            }
            _ => self.ch.pop().ok_or(RecvError),
        }
    }

    /// Like [`Receiver::recv`] but the scheduler may fire the timeout
    /// at any point (the `Duration` value is ignored — model time is
    /// schedule order, not wall time).
    pub fn recv_timeout(&self, _dur: Duration) -> Result<T, RecvTimeoutError> {
        match rt::ctx() {
            Some(cx) if !std::thread::panicking() => {
                let obj = self.ch.obj(&cx);
                match cx.rt.op(cx.vtid, Op::Recv {
                    ch: obj,
                    timed: true,
                }) {
                    Grant::RecvData => Ok(self.ch.pop().expect("granted recv finds a value")),
                    Grant::RecvTimedOut => Err(RecvTimeoutError::Timeout),
                    _ => Err(RecvTimeoutError::Disconnected),
                }
            }
            _ => self.ch.pop().ok_or(RecvTimeoutError::Disconnected),
        }
    }

    /// Non-blocking receive; the scheduler decides the outcome.
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        match rt::ctx() {
            Some(cx) if !std::thread::panicking() => {
                let obj = self.ch.obj(&cx);
                match cx.rt.op(cx.vtid, Op::TryRecv { ch: obj }) {
                    Grant::TryRecvData => Ok(self.ch.pop().expect("granted recv finds a value")),
                    Grant::TryRecvEmpty => Err(TryRecvError::Empty),
                    _ => Err(TryRecvError::Disconnected),
                }
            }
            _ => self.ch.pop().ok_or(TryRecvError::Disconnected),
        }
    }

    /// Blocking iterator over received values, ending at disconnect.
    pub fn iter(&self) -> Iter<'_, T> {
        Iter { rx: self }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        if let Some(cx) = rt::ctx() {
            let obj = self.ch.obj(&cx);
            cx.rt
                .effect_then_yield(cx.vtid, Effect::ReceiverDrop(obj), "receiver_drop");
        }
    }
}

/// Iterator returned by [`Receiver::iter`].
pub struct Iter<'a, T> {
    rx: &'a Receiver<T>,
}

impl<T> Iterator for Iter<'_, T> {
    type Item = T;
    fn next(&mut self) -> Option<T> {
        self.rx.recv().ok()
    }
}

impl<'a, T> IntoIterator for &'a Receiver<T> {
    type Item = T;
    type IntoIter = Iter<'a, T>;
    fn into_iter(self) -> Iter<'a, T> {
        self.iter()
    }
}
