//! Deterministic concurrency model checking (`--cfg spidr_model`).
//!
//! A loom-style, zero-dependency bounded model checker for the crate's
//! concurrency layer (DESIGN.md §Correctness). Code written against
//! [`crate::sync`] compiles to plain `std` in release builds; under
//! `RUSTFLAGS="--cfg spidr_model"` every lock / condvar wait / notify /
//! channel send / recv / atomic op becomes a *scheduling point* routed
//! through a cooperative scheduler ([`rt`]) that serializes the
//! program's threads and explores interleavings exhaustively:
//!
//! * **DFS over scheduling decisions** — each scheduling point records
//!   the candidate set and the index chosen; the explorer backtracks
//!   over the deepest untried alternative and replays the prefix
//!   deterministically.
//! * **Preemption bound** — switching away from a thread that could
//!   have kept running costs one unit of budget
//!   ([`Config::preemption_bound`]); most real bugs need ≤2.
//! * **State-hash pruning** — states whose per-object operation
//!   histories match a visited state (Mazurkiewicz trace equivalence,
//!   64-bit hash) are pruned.
//! * **Failure detection** — deadlock (no enabled op and no timeout to
//!   fire), lost wakeup (every live thread in an untimed condvar
//!   wait), [`model_assert!`] violations, panics, and livelock (step
//!   limit); every failure carries a schedule that [`replay`] reruns
//!   to the same outcome.
//!
//! ```text
//! RUSTFLAGS="--cfg spidr_model" cargo test --test model
//! ```

use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, OnceLock};

pub mod chan;
pub(crate) mod rt;
pub mod shim;
pub mod thread_shim;

/// Exploration limits for [`explore`].
#[derive(Clone, Debug)]
pub struct Config {
    /// Maximum number of *preemptions* (context switches away from a
    /// thread that could have continued) per execution.
    pub preemption_bound: usize,
    /// Hard cap on the number of executions explored.
    pub max_executions: u64,
    /// Hard cap on scheduling points per execution (livelock guard).
    pub max_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            preemption_bound: 2,
            max_executions: 500_000,
            max_steps: 20_000,
        }
    }
}

impl Config {
    /// The default configuration (preemption bound 2).
    pub fn new() -> Config {
        Config::default()
    }

    /// Same configuration with a different preemption bound.
    pub fn with_bound(mut self, bound: usize) -> Config {
        self.preemption_bound = bound;
        self
    }
}

/// Why an execution failed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// Unfinished threads exist but no operation is enabled and no
    /// timeout can fire.
    Deadlock,
    /// Deadlock where every live thread sits in an *untimed* condvar
    /// wait: the classic missed-notify window.
    LostWakeup,
    /// A [`model_assert!`] fired (message inside).
    Assertion(String),
    /// User code panicked (message inside).
    Panic(String),
    /// The execution exceeded [`Config::max_steps`] scheduling points.
    StepLimit,
}

impl std::fmt::Display for FailureKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FailureKind::Deadlock => write!(f, "deadlock"),
            FailureKind::LostWakeup => write!(f, "lost wakeup"),
            FailureKind::Assertion(m) => write!(f, "assertion failed: {m}"),
            FailureKind::Panic(m) => write!(f, "panic: {m}"),
            FailureKind::StepLimit => write!(f, "step limit exceeded (livelock?)"),
        }
    }
}

/// A failing execution: what went wrong plus the schedule to rerun it.
#[derive(Clone, Debug)]
pub struct Failure {
    /// Failure class.
    pub kind: FailureKind,
    /// The scheduling choices of the failing execution; feed to
    /// [`replay`] for a deterministic rerun.
    pub schedule: Vec<usize>,
    /// Human-readable decision trace (one line per scheduling point)
    /// ending with the final per-thread states.
    pub trace: String,
}

/// The result of an [`explore`] run.
#[derive(Debug)]
pub struct Report {
    /// Executions started (including pruned ones).
    pub executions: u64,
    /// Executions cut short by state-hash pruning.
    pub pruned: u64,
    /// The first failure found, if any (exploration stops on it).
    pub failure: Option<Failure>,
}

impl Report {
    /// Panic with the full schedule trace if a failure was found.
    ///
    /// The panic message embeds the failure kind, the replayable
    /// schedule, and the decision trace, so a CI log alone is enough
    /// to pin a regression model.
    pub fn assert_ok(&self) {
        if let Some(f) = &self.failure {
            panic!(
                "model exploration failed after {} executions ({} pruned): {}\nschedule: {:?}\ntrace:\n{}",
                self.executions, self.pruned, f.kind, f.schedule, f.trace
            );
        }
    }
}

/// Silence the panic hook for model-internal unwinds: every abort
/// tears threads down via sentinel panics, and user-code failures are
/// reported through [`Failure`], not stderr spam (thousands of
/// executions would otherwise print thousands of backtraces).
fn install_hook() {
    static HOOK: OnceLock<()> = OnceLock::new();
    HOOK.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let sentinel = info.payload().is::<rt::Abort>()
                || info.payload().is::<rt::ModelFailureMsg>()
                || rt::ctx().is_some();
            if !sentinel {
                prev(info);
            }
        }));
    });
}

/// Exhaustively explore the interleavings of `body` within `cfg`'s
/// bounds. `body` runs once per execution as virtual thread 0 and may
/// spawn more threads through `crate::sync::thread`; exploration stops
/// at the first failure or when the bounded space is exhausted.
pub fn explore<F: Fn()>(cfg: Config, body: F) -> Report {
    install_hook();
    let mut visited: HashSet<u64> = HashSet::new();
    let mut prefix: Vec<usize> = Vec::new();
    let mut executions = 0u64;
    let mut pruned = 0u64;
    loop {
        let rt = Arc::new(rt::Rt::new(&cfg, prefix, std::mem::take(&mut visited), true));
        let _ = catch_unwind(AssertUnwindSafe(|| rt::run_vthread(&rt, 0, &body)));
        rt.wait_quiescent();
        executions += 1;
        let (trail, was_pruned, failure, vis) = rt.take_outcome();
        visited = vis;
        if was_pruned {
            pruned += 1;
        }
        if failure.is_some() {
            return Report {
                executions,
                pruned,
                failure,
            };
        }
        match rt::Rt::next_prefix(&trail, cfg.preemption_bound) {
            Some(p) if executions < cfg.max_executions => prefix = p,
            _ => {
                return Report {
                    executions,
                    pruned,
                    failure: None,
                }
            }
        }
    }
}

/// Re-run one pinned execution: follow `schedule` exactly (continuing
/// with the default choice past its end) and return the failure it
/// reproduces, if any. Deterministic: replaying the schedule out of a
/// [`Failure`] yields the same [`FailureKind`].
pub fn replay<F: FnOnce()>(cfg: Config, schedule: &[usize], body: F) -> Option<Failure> {
    install_hook();
    let rt = Arc::new(rt::Rt::new(&cfg, schedule.to_vec(), HashSet::new(), false));
    let _ = catch_unwind(AssertUnwindSafe(|| rt::run_vthread(&rt, 0, body)));
    rt.wait_quiescent();
    let (_, _, failure, _) = rt.take_outcome();
    failure
}

/// Assert an invariant inside a model body. On violation the current
/// execution aborts and [`explore`] reports
/// [`FailureKind::Assertion`] with the failing schedule. Outside a
/// model run it degrades to a plain `assert!`.
#[macro_export]
macro_rules! model_assert {
    ($cond:expr) => {
        $crate::model_assert!($cond, "{}", stringify!($cond))
    };
    ($cond:expr, $($msg:tt)+) => {
        if !$cond {
            $crate::check::model_violation(format!($($msg)+));
        }
    };
}

/// Assert two expressions are equal inside a model body (see
/// [`model_assert!`]).
#[macro_export]
macro_rules! model_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        $crate::model_assert!(a == b, "{:?} != {:?} ({} vs {})", a, b, stringify!($a), stringify!($b));
    }};
}

/// Raise a model invariant violation (the expansion target of
/// [`model_assert!`]; not meant to be called directly).
pub fn model_violation(msg: String) -> ! {
    if rt::ctx().is_some() {
        std::panic::panic_any(rt::ModelFailureMsg(msg));
    }
    panic!("{msg}");
}
