//! Model-checked thread creation: `spawn`, `spawn_named`, and
//! `thread::scope` that register every spawned thread as a virtual
//! thread of the current execution. Compiled only under
//! `cfg(spidr_model)`; outside a model run everything passes straight
//! through to `std::thread`.
//!
//! Real OS threads still back every virtual thread (the scheduler
//! serializes them, it does not re-implement stacks), so scoped
//! borrows work exactly as with `std::thread::scope`. The one extra
//! mechanism: a model scope performs *scheduler-aware* joins of its
//! spawned virtual threads before the underlying `std` scope's
//! implicit join, so the OS-level join can never block a thread the
//! scheduler still considers runnable.

use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

pub use std::thread::available_parallelism;

use super::rt::{self, Op};

/// Handle to a spawned (possibly model-registered) thread.
pub struct JoinHandle<T> {
    vtid: Option<usize>,
    inner: std::thread::JoinHandle<T>,
}

impl<T> JoinHandle<T> {
    /// Wait for the thread to finish (a scheduling point under the
    /// model) and return its result.
    pub fn join(self) -> std::thread::Result<T> {
        if let Some(vtid) = self.vtid {
            if let Some(cx) = rt::ctx() {
                if !std::thread::panicking() {
                    cx.rt.op(cx.vtid, Op::Join { tid: vtid });
                }
            }
        }
        self.inner.join()
    }

    /// Whether the thread has finished (non-blocking).
    pub fn is_finished(&self) -> bool {
        self.inner.is_finished()
    }
}

/// Spawn a thread (`std::thread::spawn`), registering it with the
/// current model execution when one is active.
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match rt::ctx() {
        None => JoinHandle {
            vtid: None,
            inner: std::thread::spawn(f),
        },
        Some(cx) => {
            let vtid = cx.rt.register_thread("spawned".to_string());
            let rt2 = Arc::clone(&cx.rt);
            let inner = std::thread::spawn(move || rt::run_vthread(&rt2, vtid, f));
            cx.rt.op(cx.vtid, Op::Yield("spawn", None));
            JoinHandle {
                vtid: Some(vtid),
                inner,
            }
        }
    }
}

/// Spawn a named thread (the facade's replacement for
/// `std::thread::Builder::new().name(..).spawn(..)`).
pub fn spawn_named<F, T>(name: &str, f: F) -> std::io::Result<JoinHandle<T>>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match rt::ctx() {
        None => Ok(JoinHandle {
            vtid: None,
            inner: std::thread::Builder::new().name(name.to_string()).spawn(f)?,
        }),
        Some(cx) => {
            let vtid = cx.rt.register_thread(name.to_string());
            let rt2 = Arc::clone(&cx.rt);
            let spawned = std::thread::Builder::new()
                .name(name.to_string())
                .spawn(move || rt::run_vthread(&rt2, vtid, f));
            match spawned {
                Ok(inner) => {
                    cx.rt.op(cx.vtid, Op::Yield("spawn", None));
                    Ok(JoinHandle {
                        vtid: Some(vtid),
                        inner,
                    })
                }
                Err(e) => {
                    // The vthread was registered but will never run:
                    // mark it finished so the execution can complete.
                    cx.rt.thread_end_external(vtid);
                    Err(e)
                }
            }
        }
    }
}

/// A scope for spawning borrowing threads (`std::thread::scope`).
pub struct Scope<'scope, 'env: 'scope> {
    std: &'scope std::thread::Scope<'scope, 'env>,
    spawned: RefCell<Vec<usize>>,
}

/// Handle to a thread spawned inside a [`scope`].
pub struct ScopedJoinHandle<'scope, T> {
    vtid: Option<usize>,
    inner: std::thread::ScopedJoinHandle<'scope, T>,
}

impl<T> ScopedJoinHandle<'_, T> {
    /// Wait for the thread to finish (a scheduling point under the
    /// model) and return its result.
    pub fn join(self) -> std::thread::Result<T> {
        if let Some(vtid) = self.vtid {
            if let Some(cx) = rt::ctx() {
                if !std::thread::panicking() {
                    cx.rt.op(cx.vtid, Op::Join { tid: vtid });
                }
            }
        }
        self.inner.join()
    }

    /// Whether the thread has finished (non-blocking).
    pub fn is_finished(&self) -> bool {
        self.inner.is_finished()
    }
}

impl<'scope, 'env> Scope<'scope, 'env> {
    /// Spawn a borrowing thread inside this scope.
    pub fn spawn<F, T>(&self, f: F) -> ScopedJoinHandle<'scope, T>
    where
        F: FnOnce() -> T + Send + 'scope,
        T: Send + 'scope,
    {
        match rt::ctx() {
            None => ScopedJoinHandle {
                vtid: None,
                inner: self.std.spawn(f),
            },
            Some(cx) => {
                let vtid = cx.rt.register_thread("scoped".to_string());
                let rt2 = Arc::clone(&cx.rt);
                let inner = self.std.spawn(move || rt::run_vthread(&rt2, vtid, f));
                self.spawned.borrow_mut().push(vtid);
                cx.rt.op(cx.vtid, Op::Yield("spawn", None));
                ScopedJoinHandle {
                    vtid: Some(vtid),
                    inner,
                }
            }
        }
    }
}

/// Create a scope for spawning borrowing threads
/// (`std::thread::scope`). Under the model, the closure's panics are
/// converted into an execution abort *before* the underlying scope
/// joins its threads, so a failing model body can never deadlock the
/// scheduler on an OS-level join.
pub fn scope<'env, F, T>(f: F) -> T
where
    F: for<'scope> FnOnce(&Scope<'scope, 'env>) -> T,
{
    std::thread::scope(|s| {
        let wrapper = Scope {
            std: s,
            spawned: RefCell::new(Vec::new()),
        };
        match catch_unwind(AssertUnwindSafe(|| f(&wrapper))) {
            Ok(out) => {
                if let Some(cx) = rt::ctx() {
                    if !std::thread::panicking() {
                        for vtid in wrapper.spawned.borrow().iter() {
                            cx.rt.op(cx.vtid, Op::Join { tid: *vtid });
                        }
                    }
                }
                out
            }
            Err(p) => {
                if let Some(cx) = rt::ctx() {
                    cx.rt.abort_with(p);
                    resume_unwind(Box::new(rt::Abort));
                }
                resume_unwind(p)
            }
        }
    })
}

/// Sleep: a plain yield scheduling point under the model (model time
/// is schedule order), a real sleep otherwise.
pub fn sleep(dur: Duration) {
    match rt::ctx() {
        Some(cx) if !std::thread::panicking() => {
            cx.rt.op(cx.vtid, Op::Yield("sleep", None));
        }
        _ => std::thread::sleep(dur),
    }
}

/// Yield: a scheduling point under the model.
pub fn yield_now() {
    match rt::ctx() {
        Some(cx) if !std::thread::panicking() => {
            cx.rt.op(cx.vtid, Op::Yield("yield", None));
        }
        _ => std::thread::yield_now(),
    }
}
