//! Table I — chip summary: power, energy efficiency (TOPS/W) and
//! throughput (GOPS) at both corners, all precisions, 95 % sparsity.
//!
//! Regenerates the measurement rows of the paper's Table I from the
//! calibrated simulator and prints them side by side with the paper's
//! silicon numbers.

mod common;

use spidr::energy::calibration::{measure, table1_targets};
use spidr::energy::model::Corner;
use spidr::quant::Precision;

fn main() {
    common::header("Table I", "chip summary @ 95 % input sparsity");
    let targets = table1_targets();

    println!(
        "{:<6} {:<14} {:>10} {:>10} {:>9} | {:>10} {:>10}",
        "prec", "corner", "GOPS", "TOPS/W", "mW", "paperGOPS", "paperT/W"
    );
    for t in &targets {
        let p = Precision::from_weight_bits(t.weight_bits).unwrap();
        for (cname, corner, pg, pt) in [
            ("50MHz/0.9V", Corner::LOW, t.gops_low, t.tops_w_low),
            ("150MHz/1.0V", Corner::HIGH, t.gops_high, t.tops_w_high),
        ] {
            let (op, secs) = common::timed(|| measure(p, corner, 0.95));
            println!(
                "{:<6} {:<14} {:>10.2} {:>10.2} {:>9.2} | {:>10.2} {:>10.2}   ({secs:.2}s)",
                format!("{}b", t.weight_bits),
                cname,
                op.gops,
                op.tops_per_watt,
                op.power_mw,
                pg,
                pt
            );
            common::emit(
                &format!("table1_gops_w{}_{}", t.weight_bits, corner.freq_mhz),
                op.sparsity,
                op.gops,
            );
            common::emit(
                &format!("table1_topsw_w{}_{}", t.weight_bits, corner.freq_mhz),
                op.sparsity,
                op.tops_per_watt,
            );
        }
    }
    println!();
    println!("paper: 4.9 mW @50MHz/0.9V and 18 mW @150MHz/1V (Table I)");
    println!("headline: up to 5 TOPS/W at 95 % sparsity, 4-bit weights");
}
