//! Fig. 13 — timestep pipelining with asynchronous handshaking.
//!
//! A Mode-2 layer (9 chained CUs + 1 NU) with per-timestep-variable
//! spike density. Compares the asynchronous-handshake makespan against
//! a lockstep-synchronous pipeline and against a worst-case-provisioned
//! constant-time pipeline, and draws the paper's Gantt-style timeline.

mod common;

use spidr::quant::Precision;
use spidr::sim::config::SimConfig;
use spidr::sim::core::SpidrCore;
use spidr::snn::layer::{Layer, NeuronConfig};
use spidr::snn::tensor::Mat;

fn main() {
    common::header(
        "Fig. 13",
        "timestep pipelining with asynchronous handshaking (Mode 2)",
    );
    // 48 input channels x 9 taps = 432 fan-in -> Mode 2.
    let layer = Layer::conv(
        (48, 8, 8),
        8,
        3,
        3,
        1,
        1,
        Mat::zeros(432, 8),
        NeuronConfig { theta: 10, ..Default::default() },
        false,
    )
    .unwrap();

    // Per-timestep density varies 5-35 %: exactly the variable
    // execution times the handshake is designed to absorb.
    let densities = [0.05, 0.35, 0.10, 0.25, 0.08, 0.30];
    let frames: Vec<_> = densities
        .iter()
        .enumerate()
        .map(|(i, &d)| common::random_plane(48, 8, 8, d, 0x13 + i as u64))
        .collect();

    let core = SpidrCore::new(SimConfig::timing_only(Precision::W4V7));
    let mut state = Mat::zeros(64, 8);
    let (_, stats) = core.run_layer(&layer, &frames, &mut state).unwrap();

    println!("mode: {:?}, tiles: {}", stats.mode, stats.tiles);
    println!("async handshake : {:>9} cycles", stats.run.cycles);
    println!("synchronous     : {:>9} cycles ({:.2}x slower)",
        stats.run.sync_cycles,
        stats.run.sync_cycles as f64 / stats.run.cycles as f64);
    println!("worst-case prov.: {:>9} cycles ({:.2}x slower)",
        stats.run.worst_case_cycles,
        stats.run.worst_case_cycles as f64 / stats.run.cycles as f64);
    common::emit("fig13_async", 0.0, stats.run.cycles as f64);
    common::emit("fig13_sync", 0.0, stats.run.sync_cycles as f64);
    common::emit("fig13_worst", 0.0, stats.run.worst_case_cycles as f64);

    // Gantt of the first tile: rows = units (CU1..CU9, NU), columns =
    // time buckets; digits mark which timestep occupies the unit.
    if let Some(tl) = &stats.example_timeline {
        println!(
            "\nfirst-tile timeline (each char ≈ {} cycles; digit = timestep):",
            (tl.makespan / 78).max(1)
        );
        let scale = (tl.makespan / 78).max(1);
        for (u, row) in tl.intervals.iter().enumerate() {
            let name = if u < tl.intervals.len() - 1 {
                format!("CU{}", u + 1)
            } else {
                "NU ".into()
            };
            let mut line = vec![b' '; 80];
            for (t, &(s, e)) in row.iter().enumerate() {
                let (a, b) = ((s / scale) as usize, (e / scale) as usize);
                for slot in line.iter_mut().take(b.min(79) + 1).skip(a) {
                    *slot = b'0' + (t % 10) as u8;
                }
            }
            println!("  {:<4} {}", name, String::from_utf8_lossy(&line));
        }
    }
    println!("\npaper: delays incurred only on data dependence; each unit starts");
    println!("as soon as it receives its inputs (Fig. 13's R/T/C/W/N stages).");
}
