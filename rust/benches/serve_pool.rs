//! Serving-tier throughput bench: the single-engine server vs the
//! sharded worker pool on the same request stream.
//!
//! Series (`DATA` lines + JSONL rows appended to `BENCH_serve.json`):
//!
//! * `serve_single_clips_per_s` — the pre-pool three-stage server
//!   (one functional engine on the calling thread), the baseline.
//! * `serve_pool_clips_per_s`  — pool throughput vs worker count.
//! * `serve_pool_speedup`     — pool / single ratio vs worker count
//!   (the acceptance series: ≥ 2× at 4 workers).
//! * `serve_pool_sim_clips_per_s` — the same request path with each
//!   worker wrapping a cycle-level `ScheduledEngine`.

mod common;

use spidr::coordinator::{
    InferenceServer, MultiCoreScheduler, PoolConfig, ReferenceEngine, ScheduledEngine,
    ServerConfig,
};
use spidr::dvs::event::{Event, Polarity};
use spidr::prop::SplitMix64;
use spidr::sim::SimConfig;
use spidr::snn::network::demo_serving_network;

fn cfg() -> ServerConfig {
    ServerConfig {
        height: 16,
        width: 16,
        timesteps: 16,
        bin_us: 1000,
        queue_depth: 4,
        ..Default::default()
    }
}

/// One synthetic DVS burst (~events random events over the clip window).
fn burst(seed: u64, events: usize) -> Vec<Event> {
    let mut rng = SplitMix64::new(seed);
    (0..events)
        .map(|_| Event {
            y: rng.below(16) as u16,
            x: rng.below(16) as u16,
            polarity: if rng.chance(0.5) { Polarity::On } else { Polarity::Off },
            t_us: rng.below(16 * 1000) as u32,
        })
        .collect()
}

fn requests(n: usize) -> Vec<Vec<Event>> {
    (0..n).map(|i| burst(1000 + i as u64, 220)).collect()
}

fn main() {
    common::header("serve", "sharded serving tier: pool vs single engine");
    let server = InferenceServer::new(cfg());
    let net = demo_serving_network(16).expect("demo workload");

    // Baseline: the single-engine three-stage server.
    const N: usize = 96;
    let mut single = ReferenceEngine::new(net.clone()).expect("engine");
    let (out, secs) = common::timed(|| server.serve(requests(N), &mut single).unwrap());
    let single_cps = N as f64 / secs;
    assert_eq!(out.0.len(), N);
    println!("single-engine serve: {N} clips in {secs:.3}s");
    common::emit("serve_single_clips_per_s", 1.0, single_cps);

    // The pool, at 1/2/4 workers, same workload and request stream.
    for workers in [1usize, 2, 4] {
        let pool = PoolConfig::with_workers(workers);
        let (out, secs) = common::timed(|| {
            server
                .serve_pool(requests(N), &pool, |_| ReferenceEngine::new(net.clone()))
                .unwrap()
        });
        let cps = N as f64 / secs;
        let (resp, metrics) = out;
        assert_eq!(resp.len(), N);
        assert!(resp.iter().enumerate().all(|(i, r)| r.id == i as u64));
        println!(
            "pool x{workers}: {N} clips in {secs:.3}s, util {:.0}%, {} stolen",
            metrics.pool_utilization() * 100.0,
            metrics.total_stolen()
        );
        common::emit("serve_pool_clips_per_s", workers as f64, cps);
        common::emit("serve_pool_speedup", workers as f64, cps / single_cps);
    }

    // The same tier with cycle-level simulated cores per worker
    // (fewer clips; the simulator is orders of magnitude heavier).
    const NSIM: usize = 12;
    for workers in [1usize, 4] {
        let pool = PoolConfig::with_workers(workers);
        let (out, secs) = common::timed(|| {
            server
                .serve_pool(requests(NSIM), &pool, |_| {
                    ScheduledEngine::new(
                        net.clone(),
                        MultiCoreScheduler::new(1, SimConfig::default()),
                    )
                })
                .unwrap()
        });
        let (resp, _) = out;
        assert_eq!(resp.len(), NSIM);
        println!("sim pool x{workers}: {NSIM} clips in {secs:.3}s");
        common::emit("serve_pool_sim_clips_per_s", workers as f64, NSIM as f64 / secs);
    }
}
