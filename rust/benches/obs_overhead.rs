//! Observability overhead bench: what does tracing cost the serve
//! fast path? (DESIGN.md §Observability — "observability must never
//! tax the fast path it observes".)
//!
//! Four interleaved variants run the same clips through the same
//! engine, min-of-N timed:
//!
//! * `baseline`  — bare `engine.infer` loop, no instrumentation calls.
//! * `disabled`  — the serve-shaped instrumentation (mint + bind +
//!   clip/dispatch/infer spans + an instant per clip) with the tracer
//!   **disabled**: the production default. Must also take zero
//!   timestamps (asserted via the `Tracer::stamps` audit counter).
//! * `sampled`   — tracer enabled at 1-in-16 sampling.
//! * `full`      — tracer enabled, every trace sampled (info only).
//!
//! Series (`DATA` lines + JSONL rows appended to `BENCH_obs.json`):
//!
//! * `tracing_overhead_ratio` — variant / baseline wall time at
//!   x = 0 (disabled), 1 (sampled 1/16), 2 (full). The acceptance
//!   gates: disabled ≤ 1.02, sampled ≤ 1.05.
//! * `obs_baseline_clips_per_s` — baseline throughput, for context.
//! * `hist_record_ns` — per-sample cost of the log-bucketed latency
//!   histogram (one array increment; no gate).
//! * `facade_overhead_ratio` — `crate::sync` facade / raw `std::sync`
//!   wall time for uncontended mutex traffic (x = 0) and bounded
//!   channel traffic (x = 1). In a release build the facade is pure
//!   re-exports (DESIGN.md §Correctness), so this pins the claim at
//!   ≤1%: the model-checkability of the concurrency layer costs the
//!   fast path nothing.

mod common;

use spidr::coordinator::{Engine, ReferenceEngine};
use spidr::obs::trace;
use spidr::obs::{tracer, LatencyHistogram};
use spidr::snn::network::demo_pipeline_network;
use spidr::snn::spikes::SpikePlane;

const TIMESTEPS: usize = 12;
const CLIPS: usize = 48;
const REPS: usize = 9;

/// The uninstrumented fast path: raw compute only.
fn run_baseline(engine: &mut ReferenceEngine, clips: &[Vec<SpikePlane>]) {
    for clip in clips {
        engine.infer(clip).unwrap();
    }
}

/// The serve-shaped instrumentation around the same compute: one trace
/// minted and bound per clip, the span set the serving tier opens
/// (root clip, dispatch, infer) plus an emit instant.
fn run_instrumented(engine: &mut ReferenceEngine, clips: &[Vec<SpikePlane>]) {
    let tr = tracer();
    for clip in clips {
        let _bind = trace::bind(tr.mint());
        let _clip = trace::span("clip");
        {
            let _dispatch = trace::span("dispatch");
        }
        let _infer = trace::span("infer");
        engine.infer(clip).unwrap();
        trace::instant("emit");
    }
}

fn main() {
    common::header(
        "obs",
        "tracing overhead: disabled / sampled / full vs uninstrumented",
    );
    let net = demo_pipeline_network(TIMESTEPS).expect("demo workload");
    let (c, h, w) = net.layers[0].in_shape;
    let clips: Vec<Vec<SpikePlane>> = (0..CLIPS)
        .map(|i| common::random_clip(c, h, w, TIMESTEPS, 0.2, 9_000 + i as u64))
        .collect();
    let mut engine = ReferenceEngine::new(net).expect("engine");

    // Warm-up: touch every code path once before timing.
    run_baseline(&mut engine, &clips[..2.min(CLIPS)]);

    let tr = tracer();
    // Variant index 0 = baseline, 1 = disabled, 2 = sampled 1/16,
    // 3 = full. Interleaved so clock/thermal drift hits all four
    // equally; min-of-REPS discards the noise.
    let mut best = [f64::INFINITY; 4];
    let mut disabled_stamps = 0u64;
    for _ in 0..REPS {
        for variant in 0..4 {
            match variant {
                0 | 1 => tr.disable(),
                2 => tr.enable(16),
                _ => tr.enable(1),
            }
            let stamps0 = tr.stamps();
            let (_, secs) = common::timed(|| match variant {
                0 => run_baseline(&mut engine, &clips),
                _ => run_instrumented(&mut engine, &clips),
            });
            if variant == 1 {
                disabled_stamps += tr.stamps() - stamps0;
            }
            best[variant] = best[variant].min(secs);
            tr.disable();
            tr.reset();
        }
    }
    assert_eq!(
        disabled_stamps, 0,
        "the disabled tracer took timestamps on the fast path"
    );

    let names = ["baseline", "disabled", "sampled 1/16", "full"];
    for (variant, secs) in best.iter().enumerate() {
        println!(
            "{:>12}: {CLIPS} clips x {TIMESTEPS} steps in {secs:.4}s (best of {REPS})",
            names[variant]
        );
    }
    common::emit("obs_baseline_clips_per_s", 1.0, CLIPS as f64 / best[0]);

    let disabled_ratio = best[1] / best[0];
    let sampled_ratio = best[2] / best[0];
    let full_ratio = best[3] / best[0];
    common::emit("tracing_overhead_ratio", 0.0, disabled_ratio);
    common::emit("tracing_overhead_ratio", 1.0, sampled_ratio);
    common::emit("tracing_overhead_ratio", 2.0, full_ratio);
    assert!(
        disabled_ratio <= 1.02,
        "disabled tracing must cost <=2% of the fast path, got {disabled_ratio:.4}x"
    );
    assert!(
        sampled_ratio <= 1.05,
        "1/16-sampled tracing must cost <=5% of the fast path, got {sampled_ratio:.4}x"
    );

    // The histogram side of the registry: one log-bucket increment
    // per sample, O(1) memory no matter the stream length.
    let mut hist = LatencyHistogram::new();
    const SAMPLES: u64 = 1 << 20;
    let (_, secs) = common::timed(|| {
        let mut rng = spidr::prop::SplitMix64::new(7);
        for _ in 0..SAMPLES {
            hist.record(rng.below(1_000_000));
        }
    });
    assert_eq!(hist.count(), SAMPLES);
    let ns = secs * 1e9 / SAMPLES as f64;
    println!("histogram record: {ns:.1} ns/sample over {SAMPLES} samples");
    common::emit("hist_record_ns", 1.0, ns);

    // The `crate::sync` facade vs raw `std::sync`: in this (release,
    // non-model) build the facade is a pure re-export, and this series
    // is the regression gate keeping it that way — a wrapper type
    // sneaking into the facade would show up as a ratio well above 1.
    const SYNC_OPS: usize = 1 << 20;
    let mut best_sync = [f64::INFINITY; 4];
    for _ in 0..REPS {
        // Variant 0/1: raw-std vs facade mutex; 2/3: raw-std vs
        // facade bounded channel. Interleaved like the tracer variants.
        let (_, s) = common::timed(|| {
            let m = std::sync::Mutex::new(0u64);
            for _ in 0..SYNC_OPS {
                *std::hint::black_box(&m).lock().unwrap() += 1;
            }
            assert_eq!(*m.lock().unwrap(), SYNC_OPS as u64);
        });
        best_sync[0] = best_sync[0].min(s);
        let (_, s) = common::timed(|| {
            let m = spidr::sync::Mutex::new(0u64);
            for _ in 0..SYNC_OPS {
                *std::hint::black_box(&m).lock().unwrap() += 1;
            }
            assert_eq!(*m.lock().unwrap(), SYNC_OPS as u64);
        });
        best_sync[1] = best_sync[1].min(s);
        let (_, s) = common::timed(|| {
            let (tx, rx) = std::sync::mpsc::sync_channel::<u64>(1);
            let mut sum = 0u64;
            for i in 0..SYNC_OPS as u64 {
                std::hint::black_box(&tx).send(i).unwrap();
                sum += rx.recv().unwrap();
            }
            assert!(sum > 0);
        });
        best_sync[2] = best_sync[2].min(s);
        let (_, s) = common::timed(|| {
            let (tx, rx) = spidr::sync::mpsc::sync_channel::<u64>(1);
            let mut sum = 0u64;
            for i in 0..SYNC_OPS as u64 {
                std::hint::black_box(&tx).send(i).unwrap();
                sum += rx.recv().unwrap();
            }
            assert!(sum > 0);
        });
        best_sync[3] = best_sync[3].min(s);
    }
    let mutex_ratio = best_sync[1] / best_sync[0];
    let chan_ratio = best_sync[3] / best_sync[2];
    println!(
        "facade overhead: mutex {mutex_ratio:.4}x, channel {chan_ratio:.4}x \
         over {SYNC_OPS} ops (best of {REPS})"
    );
    common::emit("facade_overhead_ratio", 0.0, mutex_ratio);
    common::emit("facade_overhead_ratio", 1.0, chan_ratio);
    assert!(
        mutex_ratio <= 1.01,
        "crate::sync mutex must cost <=1% over std, got {mutex_ratio:.4}x"
    );
    assert!(
        chan_ratio <= 1.01,
        "crate::sync channel must cost <=1% over std, got {chan_ratio:.4}x"
    );
}
