//! Fig. 5 — variation in input sparsity across layers of the two
//! Table-II networks (gesture recognition and optical flow).
//!
//! The paper's observation: the flow net's second layer sees 60–75 %
//! sparsity (AER-hostile) while later layers range 75–99 % — the
//! motivation for sparsity handling that works across the whole range.
//!
//! Runs the reference executor over synthetic clips (trained weight
//! bundles when artifacts exist, synthetic weights otherwise) and
//! prints per-layer min/mean/max input sparsity.

mod common;

use spidr::dvs::flow_scene::{make_flow_scene, FlowSceneConfig};
use spidr::dvs::gesture::{make_gesture, GestureConfig};
use spidr::quant::Precision;
use spidr::snn::layer::NeuronConfig;
use spidr::snn::network::{flow_network, gesture_network, Network, NetworkBuilder};
use spidr::snn::spikes::{SparsityStats, SpikePlane};
use spidr::snn::tensor::Mat;
use spidr::snn::WeightBundle;

/// Synthetic fallback networks when no trained artifacts exist.
fn synthetic_flow(h: usize, w: usize) -> Network {
    let mut rng = spidr::prop::SplitMix64::new(0xF10F);
    let mut b = NetworkBuilder::new("flow-syn", Precision::W4V7, 10, (2, h, w));
    let chans = [2usize, 32, 32, 32, 32, 32, 32, 32, 2];
    for i in 0..8 {
        let f = chans[i] * 9;
        let mut m = Mat::zeros(f, chans[i + 1]);
        for r in 0..f {
            for c in 0..chans[i + 1] {
                m.set(r, c, (rng.below(15) as i32) - 7);
            }
        }
        let neuron = NeuronConfig { theta: 24, leak: 2, leaky: true, ..Default::default() };
        b = b.conv3x3(chans[i + 1], m, neuron, i == 7).unwrap();
    }
    b.build().unwrap()
}

fn synthetic_gesture(h: usize, w: usize) -> Network {
    let mut rng = spidr::prop::SplitMix64::new(0x6E5);
    let mut b = NetworkBuilder::new("gesture-syn", Precision::W4V7, 10, (2, h, w));
    let chans = [2usize, 16, 16, 16, 16, 16];
    for i in 0..5 {
        let f = chans[i] * 9;
        let mut m = Mat::zeros(f, chans[i + 1]);
        for r in 0..f {
            for c in 0..chans[i + 1] {
                m.set(r, c, (rng.below(15) as i32) - 7);
            }
        }
        let neuron = NeuronConfig { theta: 20, ..Default::default() };
        b = b.conv3x3(chans[i + 1], m, neuron, false).unwrap();
        if i == 2 || i == 4 {
            b = b.pool(2, 2);
        }
    }
    b = b.pool(8, 8);
    let (c, hh, ww) = b.shape();
    let f = c * hh * ww;
    let mut m = Mat::zeros(f, 11);
    for r in 0..f {
        for cc in 0..11 {
            m.set(r, cc, (rng.below(15) as i32) - 7);
        }
    }
    b.fc(11, m, NeuronConfig::default(), true).unwrap().build().unwrap()
}

fn load_or_synthetic(task: &str, h: usize, w: usize) -> (Network, &'static str) {
    let path = format!("artifacts/weights/{task}_w4.swb");
    if let Ok(bundle) = WeightBundle::load(&path) {
        let net = match task {
            "gesture" => gesture_network(&bundle, Precision::W4V7, h, w, 10),
            _ => flow_network(&bundle, Precision::W4V7, h, w, 10),
        };
        if let Ok(n) = net {
            return (n, "trained");
        }
    }
    match task {
        "gesture" => (synthetic_gesture(h, w), "synthetic"),
        _ => (synthetic_flow(h, w), "synthetic"),
    }
}

fn report(name: &str, net: &Network, clips: &[Vec<SpikePlane>]) {
    let n_layers = net.stateful_layers().count();
    let mut stats: Vec<SparsityStats> = (0..n_layers).map(|_| SparsityStats::new()).collect();
    for frames in clips {
        let mut state = net.init_state().unwrap();
        for f in frames {
            let t = net.step(f, &mut state).unwrap();
            for (i, (&s, &c)) in t
                .layer_input_spikes
                .iter()
                .zip(&t.layer_input_cells)
                .enumerate()
            {
                stats[i].record_counts(s, c);
            }
        }
    }
    println!("\n{name}:");
    println!("{:>7} {:>9} {:>9} {:>9}", "layer", "min%", "mean%", "max%");
    for (i, s) in stats.iter().enumerate() {
        println!(
            "{:>7} {:>9.1} {:>9.1} {:>9.1}",
            format!("L{}", i + 1),
            s.min_sparsity() * 100.0,
            s.mean_sparsity() * 100.0,
            s.max_sparsity() * 100.0
        );
        common::emit(&format!("fig5_{name}_mean"), (i + 1) as f64, s.mean_sparsity());
        common::emit(&format!("fig5_{name}_min"), (i + 1) as f64, s.min_sparsity());
        common::emit(&format!("fig5_{name}_max"), (i + 1) as f64, s.max_sparsity());
    }
}

fn main() {
    common::header("Fig. 5", "input sparsity across network layers");
    let full = std::env::args().any(|a| a == "--full");
    // Reduced geometry by default (weights are resolution-independent);
    // --full uses the Table-II deploy sizes (288x384 / 64x64).
    let (fh, fw) = if full { (288, 384) } else { (96, 128) };
    let (gh, gw) = (64, 64);

    let (flow_net, src_f) = load_or_synthetic("flow", fh, fw);
    let flow_clips: Vec<_> = (0..3)
        .map(|i| {
            make_flow_scene(
                40 + i,
                &FlowSceneConfig {
                    height: fh,
                    width: fw,
                    timesteps: 10,
                    num_blobs: 24 * (fh * fw) / (48 * 64),
                    noise_rate: 0.005,
                },
            )
            .frames
        })
        .collect();
    report(&format!("optical-flow ({src_f}, {fh}x{fw})"), &flow_net, &flow_clips);

    let (gest_net, src_g) = load_or_synthetic("gesture", gh, gw);
    let gest_clips: Vec<_> = (0..5)
        .map(|i| {
            make_gesture(
                (i % 11) as usize,
                70 + i,
                &GestureConfig {
                    height: gh,
                    width: gw,
                    timesteps: 10,
                    noise_rate: 0.008,
                },
            )
            .frames
        })
        .collect();
    report(&format!("gesture ({src_g}, {gh}x{gw})"), &gest_net, &gest_clips);

    println!("\npaper: flow L2 sparsity 60-75 %; L3 75-99 %; gesture 75-99 %");
}
