//! Distributed-serving latency bench: what the wire costs
//! (DESIGN.md §Distributed).
//!
//! Series (`DATA` lines + JSONL rows appended to
//! `BENCH_distributed.json`):
//!
//! * `clip_latency_local_us`    — `ReferenceEngine` single-clip
//!   latency (no wire), the baseline; x = 1.
//! * `clip_latency_loopback_us` — `DistributedEngine` over in-process
//!   loopback byte pipes vs shard count (codec + windowing +
//!   reassembly, no sockets).
//! * `clip_latency_tcp_us`      — the same constellation over real
//!   localhost TCP sockets vs shard count (the acceptance series:
//!   loopback-vs-TCP separates protocol cost from socket cost).
//! * `distributed_overhead`     — TCP / local latency ratio vs shard
//!   count (how much the wire costs on a workload this small; deeper
//!   groups amortize it).
//! * `clip_latency_failover_us` — the recovery clip of a 2-shard ×
//!   2-replica constellation after one replica is severed mid-stream
//!   (pays the group re-push + frame replay).
//! * `clip_latency_degraded_us` — steady-state clip latency on the
//!   surviving replica after the failover.
//! * `distributed_batched_clips_per_s` — throughput of one 64-clip v3
//!   lane batch through a 2-shard loopback constellation (64 clips ÷
//!   batch wall time).
//! * `wire_amortization_ratio` — scalar wire frames ÷ lane wire frames
//!   for the same 64 clips on the same constellation (how much of the
//!   per-frame wire cost the lane batch amortizes; 64·(T+1)/(T+2) ≈
//!   59x at T=12).
//! * `planner_modeled_us` / `planner_measured_us` — the deployment
//!   planner's makespan model (DESIGN.md §Planner, calibrated from a
//!   reference clip + a 1-shard loopback clip) against measured clip
//!   latency per topology: x = 2 (2-shard plain), 3 (3-shard plain),
//!   4 (3-shard skewed: one 64 MB/s, 1.5 ms link). Asserted to agree
//!   within 30% on every topology.
//! * `window_autotune_speedup` — lane-batch wall-time ratio of the
//!   fixed default window schedule over the stall-driven retuned one
//!   on the skewed constellation (asserted ≥ 1.2x, bit-identical).
//!
//! Outputs are asserted bit-identical to the reference on every
//! topology — including across the replica kill and under every window
//! schedule — so this bench doubles as an end-to-end equivalence smoke
//! over both transports, the failover path, and the retuner.

mod common;

use spidr::coordinator::{Engine, ReferenceEngine};
use spidr::net::plan::modeled_clip_us;
use spidr::net::{
    CostModel, DistributedConfig, DistributedEngine, LinkSpec, ShardHost, TcpTransport, Transport,
};
use spidr::snn::network::demo_pipeline_network;
use spidr::snn::spikes::SpikePlane;

const TIMESTEPS: usize = 12;
const REPS: usize = 5;

/// Best-of-N single-clip latency in microseconds.
fn best_latency_us<E: Engine>(engine: &mut E, clip: &[SpikePlane]) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let (_, secs) = common::timed(|| engine.infer(clip).unwrap());
        best = best.min(secs * 1e6);
    }
    best
}

/// Emit one planner model-vs-measurement pair and gate the 30%
/// agreement band the plan is only trustworthy inside.
fn check_model(x: f64, modeled_us: f64, measured_us: f64) {
    println!(
        "planner model @ x={x}: modeled {modeled_us:.0} us vs measured {measured_us:.0} us \
         ({:+.0}%)",
        (modeled_us / measured_us - 1.0) * 100.0
    );
    common::emit("planner_modeled_us", x, modeled_us);
    common::emit("planner_measured_us", x, measured_us);
    assert!(
        (modeled_us / measured_us - 1.0).abs() <= 0.30,
        "planner model off by more than 30% at x={x}: modeled {modeled_us:.0} us, \
         measured {measured_us:.0} us"
    );
}

fn main() {
    common::header(
        "distributed",
        "distributed shard serving: loopback vs TCP clip latency",
    );
    let net = demo_pipeline_network(TIMESTEPS).expect("demo workload");
    let clip = common::random_clip(2, 24, 24, TIMESTEPS, 0.2, 42);

    let mut local = ReferenceEngine::new(net.clone()).expect("reference engine");
    let want = local.infer(&clip).expect("reference clip");
    let local_us = best_latency_us(&mut local, &clip);
    println!("local reference: {local_us:.0} us/clip ({TIMESTEPS} steps, 5 stateful layers)");
    common::emit("clip_latency_local_us", 1.0, local_us);

    // Calibrate the planner's two cost knobs on this machine: the
    // reference clip pins per-synop compute; a 1-shard plain loopback
    // clip pins per-frame wire overhead (DESIGN.md §Planner).
    let mut calib = DistributedEngine::loopback(net.clone(), &DistributedConfig::with_shards(1))
        .expect("calibration constellation");
    let got = calib.infer(&clip).expect("calibration clip");
    assert_eq!(got, want, "calibration output diverged");
    let calib_us = best_latency_us(&mut calib, &clip);
    let cost = CostModel::calibrate(&net, local_us, calib_us);
    println!(
        "calibrated cost model: {:.2e} us/synop, {:.1} us/frame overhead",
        cost.per_synop_us, cost.per_frame_overhead_us
    );

    for shards in [2usize, 3] {
        // Loopback: the whole wire path, no sockets.
        let cfg = DistributedConfig::with_shards(shards);
        let mut loopback =
            DistributedEngine::loopback(net.clone(), &cfg).expect("loopback constellation");
        let got = loopback.infer(&clip).expect("loopback clip");
        assert_eq!(got, want, "loopback output diverged at {shards} shards");
        let loopback_us = best_latency_us(&mut loopback, &clip);
        common::emit("clip_latency_loopback_us", shards as f64, loopback_us);

        // Planner model vs measurement on the plain topology: loopback
        // links, the engine's own groups and uniform default windows.
        let plain_links = vec![LinkSpec::loopback(); shards];
        let modeled = modeled_clip_us(
            &net,
            loopback.groups(),
            &plain_links,
            loopback.windows(),
            &cost,
        )
        .expect("modeled makespan");
        check_model(shards as f64, modeled, loopback_us);

        // TCP: the same shard hosts behind real localhost sockets.
        let mut links: Vec<Box<dyn Transport>> = Vec::new();
        for _ in 0..shards {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
            let addr = listener.local_addr().expect("addr");
            let shard_net = net.clone();
            std::thread::spawn(move || {
                let (stream, _) = listener.accept().expect("accept");
                let mut link = TcpTransport::from_stream(stream);
                ShardHost::new(shard_net).serve(&mut link).expect("shard session");
            });
            links.push(Box::new(TcpTransport::connect(addr).expect("connect")));
        }
        let mut tcp = DistributedEngine::connect(net.clone(), links, cfg.window)
            .expect("tcp constellation");
        let got = tcp.infer(&clip).expect("tcp clip");
        assert_eq!(got, want, "TCP output diverged at {shards} shards");
        let tcp_us = best_latency_us(&mut tcp, &clip);

        println!(
            "{shards} shards: loopback {loopback_us:.0} us/clip, tcp {tcp_us:.0} us/clip \
             ({:.2}x local)",
            tcp_us / local_us
        );
        common::emit("clip_latency_tcp_us", shards as f64, tcp_us);
        common::emit("distributed_overhead", shards as f64, tcp_us / local_us);
    }

    // Failover (ISSUE 5): a replicated constellation absorbs a
    // mid-stream replica kill with zero lost clips — the recovery clip
    // pays the re-push + replay, later clips run degraded on the
    // survivor. Output stays bit-identical throughout (the oracle).
    let cfg = DistributedConfig::replicated(2, 2);
    let mut replicated =
        DistributedEngine::loopback(net.clone(), &cfg).expect("replicated constellation");
    let got = replicated.infer(&clip).expect("replicated clip");
    assert_eq!(got, want, "replicated output diverged at 2x2");
    // After one clip the least-loaded pick is replica 1 — sever it on
    // every hop so the next clip must run the failover path.
    for hop in 0..replicated.groups().len() {
        replicated.sever_replica(hop, 1).expect("sever replica");
    }
    let (got, secs) = common::timed(|| replicated.infer(&clip).expect("failover clip"));
    assert_eq!(got, want, "failover output diverged at 2x2");
    assert_eq!(
        replicated.failovers(),
        replicated.groups().len() as u64,
        "every hop must have absorbed exactly one failover"
    );
    let failover_us = secs * 1e6;
    let degraded_us = best_latency_us(&mut replicated, &clip);
    println!(
        "2x2 failover: recovery clip {failover_us:.0} us (re-push + replay), \
         degraded steady state {degraded_us:.0} us/clip"
    );
    common::emit("clip_latency_failover_us", 2.0, failover_us);
    common::emit("clip_latency_degraded_us", 2.0, degraded_us);

    // Lane batching (ISSUE 7): 64 clips as one v3 lane batch per hop,
    // then the same 64 clips as scalar sessions on the same
    // constellation — the wire-frame counters give the amortization
    // ratio, and the reference outputs gate both paths.
    let cfg = DistributedConfig::with_shards(2);
    let mut batched =
        DistributedEngine::loopback(net.clone(), &cfg).expect("batched constellation");
    assert_eq!(batched.max_batch(), 64, "loopback shards must negotiate v3");
    let clips: Vec<Vec<SpikePlane>> = (0..64u64)
        .map(|i| common::random_clip(2, 24, 24, TIMESTEPS, 0.2, 100 + i))
        .collect();
    let mut want_batch = Vec::new();
    for c in &clips {
        want_batch.push(local.infer(c).expect("reference clip"));
    }
    let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
    let (got, secs) = common::timed(|| batched.infer_batch(&refs).expect("lane batch"));
    assert_eq!(got, want_batch, "batched outputs diverged from the reference");
    let (scalar0, lane) = batched.wire_frames();
    assert_eq!(scalar0, 0, "a lane-batched run sent scalar spike frames");
    for (i, c) in clips.iter().enumerate() {
        let got = batched.infer(c).expect("scalar clip");
        assert_eq!(got, want_batch[i], "scalar output diverged on clip {i}");
    }
    let (scalar, lane1) = batched.wire_frames();
    assert_eq!(lane1, lane, "a scalar run sent lane frames");
    let clips_per_s = 64.0 / secs;
    let ratio = scalar as f64 / lane as f64;
    println!(
        "64-clip lane batch over 2 shards: {clips_per_s:.0} clips/s, \
         {lane} lane frames vs {scalar} scalar frames ({ratio:.1}x amortization)"
    );
    common::emit("distributed_batched_clips_per_s", 64.0, clips_per_s);
    common::emit("wire_amortization_ratio", 64.0, ratio);

    // Planner vs measurement on a skewed wire topology, then the
    // stall-driven retuner (DESIGN.md §Planner): the middle hop of a
    // 3-shard constellation crosses a throttled 64 MB/s, 1.5 ms link,
    // so the uniform default window leaves most of that hop's
    // bandwidth-delay product unfilled.
    let skew_links = [
        LinkSpec::loopback(),
        LinkSpec::new(64 << 20, 1_500),
        LinkSpec::loopback(),
    ];
    let cfg = DistributedConfig::with_shards(3);
    let mut skewed = DistributedEngine::loopback_throttled(net.clone(), &cfg, &skew_links)
        .expect("skewed constellation");
    let got = skewed.infer(&clip).expect("skewed clip");
    assert_eq!(got, want, "skewed output diverged");
    let skewed_us = best_latency_us(&mut skewed, &clip);
    let modeled = modeled_clip_us(&net, skewed.groups(), &skew_links, skewed.windows(), &cost)
        .expect("skewed modeled makespan");
    check_model(4.0, modeled, skewed_us);

    // Fixed default windows vs stall-driven retuning on lane batches
    // over the same skewed constellation: the congestion-adaptive
    // acceptance gate.
    const LANES: u64 = 8;
    let bclips: Vec<Vec<SpikePlane>> = (0..LANES)
        .map(|i| common::random_clip(2, 24, 24, TIMESTEPS, 0.2, 500 + i))
        .collect();
    let mut bwant = Vec::new();
    for c in &bclips {
        bwant.push(local.infer(c).expect("reference clip"));
    }
    let brefs: Vec<&[SpikePlane]> = bclips.iter().map(|c| c.as_slice()).collect();
    let batch_best = |engine: &mut DistributedEngine| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let (got, secs) = common::timed(|| engine.infer_batch(&brefs).expect("lane batch"));
            assert_eq!(got, bwant, "skewed lane-batch outputs diverged");
            best = best.min(secs * 1e6);
        }
        best
    };
    let fixed_us = batch_best(&mut skewed);
    let mut tuned = DistributedEngine::loopback_throttled(net.clone(), &cfg, &skew_links)
        .expect("retuned constellation");
    for _ in 0..8 {
        let got = tuned.infer_batch(&brefs).expect("retune batch");
        assert_eq!(got, bwant, "outputs diverged during retuning");
        if !tuned.retune_windows(1, 16) {
            break;
        }
    }
    let tuned_us = batch_best(&mut tuned);
    let speedup = fixed_us / tuned_us;
    println!(
        "skewed 3-shard constellation: fixed windows {:?} {fixed_us:.0} us/batch vs \
         retuned {:?} {tuned_us:.0} us/batch ({speedup:.2}x)",
        skewed.windows(),
        tuned.windows(),
    );
    common::emit("window_autotune_speedup", LANES as f64, speedup);
    assert!(
        speedup >= 1.2,
        "stall-driven window retuning must beat the fixed default by >=1.2x, got {speedup:.2}x"
    );
}
