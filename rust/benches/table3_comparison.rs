//! Table III — comparison with contemporary digital SNN accelerators.
//!
//! Literature rows are constants from the cited papers; the SpiDR row
//! is measured from the simulator, including the `energy ∝ tech²`
//! scaling to 28 nm used in the paper's footnote d.

mod common;

use spidr::energy::calibration::measure;
use spidr::energy::model::Corner;
use spidr::energy::tech::{literature_rows, scale_efficiency_to_node};
use spidr::quant::ALL_PRECISIONS;

fn main() {
    common::header("Table III", "comparison with digital SNN accelerators");

    println!(
        "{:<13} {:<12} {:>6} {:>8}  {:<16} {:<8} {:<6} {:<6}  efficiency",
        "chip", "venue", "nm", "mm2", "compute", "wprec", "recfg", "modtr"
    );

    // SpiDR row (this work), measured from the simulator.
    let mut eff_parts = Vec::new();
    for &p in &ALL_PRECISIONS {
        let op = measure(p, Corner::LOW, 0.95);
        let scaled = scale_efficiency_to_node(op.tops_per_watt, 65.0, 28.0);
        eff_parts.push(format!(
            "{}b: {:.2} ({:.2})",
            p.weight_bits(),
            op.tops_per_watt,
            scaled
        ));
        common::emit(
            &format!("table3_spidr_topsw_w{}", p.weight_bits()),
            65.0,
            op.tops_per_watt,
        );
    }
    println!(
        "{:<13} {:<12} {:>6} {:>8}  {:<16} {:<8} {:<6} {:<6}  {} TOPS/W @50MHz,0.9V (28nm-scaled in parens)",
        "SpiDR (sim)", "this work", 65, 3.12, "Digital CIM", "4/6/8", "yes", "no",
        eff_parts.join(", ")
    );
    println!(
        "{:<13} {:<12} {:>6} {:>8}  {:<16} {:<8} {:<6} {:<6}  paper: 5 / 3.34 / 2.5 (26.95 / 18 / 13.5)",
        "SpiDR (chip)", "paper", 65, 3.12, "Digital CIM", "4/6/8", "yes", "no"
    );

    for r in literature_rows() {
        let scaled = r
            .tops_w_native
            .map(|t| {
                format!(
                    " [{:.1} T/W @28nm]",
                    scale_efficiency_to_node(t, r.tech_nm, 28.0)
                )
            })
            .unwrap_or_default();
        println!(
            "{:<13} {:<12} {:>6} {:>8}  {:<16} {:<8} {:<6} {:<6}  {}{}",
            r.name,
            r.venue,
            r.tech_nm,
            r.area_mm2,
            r.compute_type,
            r.weight_precision,
            if r.reconfigurable { "yes" } else { "no" },
            if r.modified_training { "yes" } else { "no" },
            r.efficiency,
            scaled
        );
    }

    println!("\nSpiDR's position (paper's argument, reproduced): competitive");
    println!("efficiency with flexible neuron models, 3 precision pairs, and");
    println!("reconfigurable network architecture without modified training.");
}
