//! Shared helpers for the bench binaries (criterion is not in this
//! environment; every bench is a `harness = false` main that prints
//! the same rows/series the paper reports, plus wall-clock info).
//!
//! Besides the grep-able `DATA` stdout lines, [`emit`] appends one
//! JSON object per line to `BENCH_<bench>.json` at the repo root
//! (e.g. `BENCH_hotpath.json`), so the perf trajectory is tracked
//! across PRs; `<bench>` is the id passed to [`header`].

#![allow(dead_code)]

use std::io::Write as _;
use std::sync::Mutex;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use spidr::prop::SplitMix64;
use spidr::snn::spikes::SpikePlane;

/// The bench id set by [`header`], used to name the JSON output file.
static CURRENT_BENCH: Mutex<Option<String>> = Mutex::new(None);

/// Print a bench header and select the JSON output file for [`emit`].
pub fn header(id: &str, what: &str) {
    println!("==================================================================");
    println!("{id} — {what}");
    println!("==================================================================");
    *CURRENT_BENCH.lock().unwrap() = Some(id.to_string());
}

/// Random binary plane at a density.
pub fn random_plane(c: usize, h: usize, w: usize, density: f64, seed: u64) -> SpikePlane {
    let mut rng = SplitMix64::new(seed);
    let mut p = SpikePlane::zeros(c, h, w);
    for i in 0..p.len() {
        if rng.chance(density) {
            p.as_mut_slice()[i] = 1;
        }
    }
    p
}

/// Random clip (frames over timesteps).
pub fn random_clip(
    c: usize,
    h: usize,
    w: usize,
    t: usize,
    density: f64,
    seed: u64,
) -> Vec<SpikePlane> {
    (0..t)
        .map(|i| random_plane(c, h, w, density, seed.wrapping_add(i as u64 * 77)))
        .collect()
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Machine-readable result: a grep-able `DATA` stdout line plus a JSON
/// line appended to `BENCH_<bench>.json`. The output directory is
/// `SPIDR_BENCH_DIR` when set, falling back to the compile-time
/// manifest root (right for `cargo bench` run in the checkout that
/// built it; set the env var when running a relocated binary).
///
/// Non-finite values are a hard error, not a silent substitution:
/// `Infinity`/`NaN` are not JSON, so one bad sample would corrupt the
/// whole `BENCH_*.json` artifact for every downstream consumer (this
/// is how the `SparsityStats` ±inf empty-band bug broke the Fig. 5
/// series). A bench that computes a non-finite number has a bug — fail
/// loudly at the source instead of laundering it into a fake `0`.
pub fn emit(series: &str, x: f64, y: f64) {
    assert!(
        x.is_finite() && y.is_finite(),
        "bench series '{series}' produced a non-finite sample (x={x}, y={y}); \
         refusing to corrupt BENCH_*.json — fix the series upstream"
    );
    println!("DATA {series} {x:.6} {y:.6}");
    let bench = CURRENT_BENCH.lock().unwrap().clone();
    if let Some(bench) = bench {
        let unix = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let line = format!(
            "{{\"bench\":\"{bench}\",\"series\":\"{series}\",\"x\":{x},\"y\":{y},\"unix\":{unix}}}\n",
        );
        let dir = std::env::var("SPIDR_BENCH_DIR")
            .unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").to_string());
        let path = format!("{dir}/BENCH_{bench}.json");
        let write = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .and_then(|mut f| f.write_all(line.as_bytes()));
        if let Err(e) = write {
            eprintln!("warning: could not append bench row to {path}: {e}");
        }
    }
}
