//! Shared helpers for the bench binaries (criterion is not in this
//! environment; every bench is a `harness = false` main that prints
//! the same rows/series the paper reports, plus wall-clock info).

#![allow(dead_code)]

use std::time::Instant;

use spidr::prop::SplitMix64;
use spidr::snn::spikes::SpikePlane;

/// Print a bench header.
pub fn header(id: &str, what: &str) {
    println!("==================================================================");
    println!("{id} — {what}");
    println!("==================================================================");
}

/// Random binary plane at a density.
pub fn random_plane(c: usize, h: usize, w: usize, density: f64, seed: u64) -> SpikePlane {
    let mut rng = SplitMix64::new(seed);
    let mut p = SpikePlane::zeros(c, h, w);
    for i in 0..p.len() {
        if rng.chance(density) {
            p.as_mut_slice()[i] = 1;
        }
    }
    p
}

/// Random clip (frames over timesteps).
pub fn random_clip(
    c: usize,
    h: usize,
    w: usize,
    t: usize,
    density: f64,
    seed: u64,
) -> Vec<SpikePlane> {
    (0..t)
        .map(|i| random_plane(c, h, w, density, seed.wrapping_add(i as u64 * 77)))
        .collect()
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Simple machine-readable result line (grep-able from bench logs).
pub fn emit(series: &str, x: f64, y: f64) {
    println!("DATA {series} {x:.6} {y:.6}");
}
