//! Fig. 10 — peripheral switching overhead: energy per macro op as a
//! function of consecutive same-parity operations.
//!
//! The paper: switching RBL switches + column peripherals after every
//! op costs ~1.5x the energy of batching 15 consecutive same-parity
//! ops; beyond FIFO depth ~16 the returns vanish (which is why the
//! silicon FIFOs are 16 deep).
//!
//! Reproduced two ways: (a) analytically from the energy model
//! (E_op + E_switch / run_length), and (b) by simulating the S2A
//! ping-pong against the naive switch-every-op policy at several FIFO
//! depths on a real spike stream.

mod common;

use spidr::energy::model::EnergyParams;
use spidr::quant::Overflow;
use spidr::sim::compute_macro::ComputeMacro;
use spidr::sim::ifspad::IfSpad;
use spidr::sim::s2a::{run_tile, S2aOptions};
use spidr::snn::tensor::Mat;

fn energy_per_op(stats: &spidr::sim::s2a::TileCuStats, e: &EnergyParams) -> f64 {
    let total = stats.macro_ops as f64 * e.macro_op(4)
        + stats.parity_switches as f64 * e.e_parity_switch;
    total / stats.macro_ops.max(1) as f64
}

fn spad_with_density(density: f64, seed: u64) -> IfSpad {
    let mut rng = spidr::prop::SplitMix64::new(seed);
    let mut s = IfSpad::new();
    s.clear(128, 16);
    for y in 0..128 {
        for x in 0..16 {
            if rng.chance(density) {
                s.write(y, x, true);
            }
        }
    }
    s
}

fn main() {
    common::header(
        "Fig. 10",
        "energy/op vs consecutive same-parity ops (peripheral switching)",
    );
    let e = EnergyParams::default();

    // (a) analytic: batching N same-parity ops amortizes one switch.
    println!("analytic model (E_op + E_switch/N):");
    println!("{:>14} {:>12} {:>9}", "batch N", "pJ/op", "vs N=1");
    let per_op_at = |n: f64| e.macro_op(4) + e.e_parity_switch / n;
    for n in [1u32, 2, 4, 8, 15, 16, 24, 32] {
        let pj = per_op_at(n as f64);
        println!("{:>14} {:>12.2} {:>9.3}", n, pj, per_op_at(1.0) / pj);
        common::emit("fig10_analytic", n as f64, pj);
    }
    println!(
        "-> batching 15 ops: {:.2}x energy reduction (paper: ~1.5x)",
        per_op_at(1.0) / per_op_at(15.0)
    );

    // (b) simulated S2A at 25 % density.
    println!("\nsimulated S2A (128x16 IFspad, 25 % density):");
    println!(
        "{:>22} {:>9} {:>11} {:>9}",
        "policy", "switches", "pJ/op", "vs naive"
    );
    let mk_cm = || ComputeMacro::new(Mat::zeros(128, 12), 7, Overflow::Wrap, false);
    let spad = spad_with_density(0.25, 0x16);
    let ready: Vec<u64> = (1..=128).collect();

    let naive = run_tile(
        &spad,
        &ready,
        &mut mk_cm(),
        &S2aOptions {
            ping_pong: false,
            ..Default::default()
        },
    );
    let naive_pj = energy_per_op(&naive, &e);
    println!(
        "{:>22} {:>9} {:>11.2} {:>9.3}",
        "switch every op", naive.parity_switches, naive_pj, 1.0
    );

    for depth in [2usize, 4, 8, 16, 32] {
        let st = run_tile(
            &spad,
            &ready,
            &mut mk_cm(),
            &S2aOptions {
                fifo_depth: depth,
                ping_pong: true,
                ..Default::default()
            },
        );
        let pj = energy_per_op(&st, &e);
        println!(
            "{:>22} {:>9} {:>11.2} {:>9.3}",
            format!("ping-pong depth {depth}"),
            st.parity_switches,
            pj,
            naive_pj / pj
        );
        common::emit("fig10_simulated", depth as f64, pj);
    }
    println!("\npaper: 16-deep FIFOs; deeper gives no significant extra energy reduction");
}
