//! Fig. 14 — energy breakdown by component at 75 % and 95 % input
//! sparsity.
//!
//! The paper's claims reproduced here:
//!   * CIM macros (compute + neuron units) dominate at both sparsities,
//!   * total energy drops by >50 % from 75 % to 95 % sparsity,
//!   * data movement is only a small fraction of the total.

mod common;

use spidr::energy::model::Corner;
use spidr::quant::Precision;
use spidr::sim::config::SimConfig;
use spidr::sim::core::SpidrCore;
use spidr::snn::layer::{Layer, NeuronConfig, ResetMode};
use spidr::snn::tensor::Mat;

fn main() {
    common::header("Fig. 14", "energy breakdown by component @75 % and 95 % sparsity");
    // A flow-net-like conv layer: Conv(32->32), 24x32 output pixels.
    let layer = Layer::conv(
        (32, 24, 32),
        32,
        3,
        3,
        1,
        1,
        Mat::zeros(288, 32),
        NeuronConfig { theta: 16, leak: 2, leaky: true, reset: ResetMode::Soft },
        false,
    )
    .unwrap();

    let mut cfg = SimConfig::timing_only(Precision::W4V7);
    cfg.corner = Corner::LOW;
    let core = SpidrCore::new(cfg);

    let mut totals = Vec::new();
    for &sparsity in &[0.75f64, 0.95] {
        let frames = common::random_clip(32, 24, 32, 4, 1.0 - sparsity, 0x14);
        let mut state = Mat::zeros(24 * 32, 32);
        let (_, stats) = core.run_layer(&layer, &frames, &mut state).unwrap();
        let mut run = stats.run;
        run.finalize_leakage(cfg.corner, &cfg.energy);
        let b = run.energy;
        let total = b.total();
        totals.push(total);
        println!("\nsparsity {:.0} % — total {:.1} nJ:", sparsity * 100.0, total / 1e3);
        let rows = [
            ("compute macros", b.compute_macro),
            ("periph. switch", b.peripheral_switch),
            ("neuron units", b.neuron_units),
            ("S2A (det+queue)", b.s2a),
            ("input loader", b.input_loader),
            ("IFmem", b.ifmem),
            ("data movement", b.data_movement),
            ("control", b.control),
            ("leakage", b.leakage),
        ];
        for (name, val) in rows {
            let share = val / total * 100.0;
            let bar = "#".repeat((share / 2.0).round() as usize);
            println!("  {:<16} {:>9.1} nJ {:>6.1} %  {}", name, val / 1e3, share, bar);
            common::emit(&format!("fig14_{}_{}", name.replace(' ', "_"), sparsity), sparsity, share);
        }
        println!(
            "  CIM share {:.1} % | data movement {:.1} %",
            b.cim_share() * 100.0,
            b.data_movement_share() * 100.0
        );
        assert!(b.cim_share() > 0.4, "CIM macros should dominate");
    }
    let drop = 1.0 - totals[1] / totals[0];
    println!(
        "\n75 % -> 95 % sparsity: total energy drops {:.1} % (paper: >50 %)",
        drop * 100.0
    );
    common::emit("fig14_energy_drop", 0.0, drop);
}
