//! Fig. 17 — peak performance (GOPS) and energy efficiency (TOPS/W) as
//! a function of input sparsity and weight precision.
//!
//! Paper claims: ~2x throughput moving 8-bit -> 4-bit at equal
//! sparsity, and ~2x moving 80 % -> 95 % sparsity at 4-bit.

mod common;

use std::time::Instant;

use spidr::energy::calibration::measure;
use spidr::energy::model::Corner;
use spidr::quant::{Precision, ALL_PRECISIONS};
use spidr::sim::config::SimConfig;
use spidr::sim::core::{LaneBank, SpidrCore};
use spidr::snn::layer::{Layer, NeuronConfig};
use spidr::snn::spikes::{LaneFrame, SpikePlane};
use spidr::snn::tensor::Mat;

fn main() {
    common::header("Fig. 17", "GOPS & TOPS/W vs sparsity x precision (50 MHz / 0.9 V)");
    let sparsities = [0.60, 0.70, 0.80, 0.85, 0.90, 0.95];

    println!(
        "{:>10} | {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8}",
        "sparsity", "4b GOPS", "6b GOPS", "8b GOPS", "4b T/W", "6b T/W", "8b T/W"
    );
    let mut table = Vec::new();
    for &s in &sparsities {
        let pts: Vec<_> = ALL_PRECISIONS
            .iter()
            .map(|&p| measure(p, Corner::LOW, s))
            .collect();
        println!(
            "{:>9.0}% | {:>8.2} {:>8.2} {:>8.2} | {:>8.2} {:>8.2} {:>8.2}",
            s * 100.0,
            pts[0].gops, pts[1].gops, pts[2].gops,
            pts[0].tops_per_watt, pts[1].tops_per_watt, pts[2].tops_per_watt
        );
        for pt in &pts {
            common::emit(&format!("fig17_gops_w{}", pt.weight_bits), s, pt.gops);
            common::emit(&format!("fig17_topsw_w{}", pt.weight_bits), s, pt.tops_per_watt);
        }
        table.push(pts);
    }

    let p4_95 = &table[5][0];
    let p8_95 = &table[5][2];
    let p4_80 = &table[2][0];
    println!("\n8b->4b @95 %: {:.2}x throughput (paper ~2x)", p4_95.gops / p8_95.gops);
    println!("80->95 % @4b: {:.2}x throughput (paper ~2x)", p4_95.gops / p4_80.gops);

    let hi4 = measure(Precision::W4V7, Corner::HIGH, 0.95);
    println!("peak: {:.2} GOPS @150 MHz/1 V, 4-bit, 95 % (paper: 73.59)", hi4.gops);

    // Batched bit-plane variant of the sweep (DESIGN.md §Perf): the
    // modelled GOPS above is per-clip silicon throughput; this row is
    // host wall-clock of the 64-lane batched datapath across the same
    // sparsity axis — one union address stream and one CIM-row sweep
    // per batch, so clips/s grows as the union stream thins out.
    const LANES: usize = 64;
    let layer = Layer::conv(
        (8, 16, 16),
        24,
        3,
        3,
        1,
        1,
        Mat::zeros(72, 24),
        NeuronConfig { theta: 16, leak: 2, leaky: true, ..Default::default() },
        false,
    )
    .unwrap();
    let core = SpidrCore::new(SimConfig::default());
    println!("\n{:>10} | {:>14}", "sparsity", "batched clip/s");
    for (si, &s) in sparsities.iter().enumerate() {
        let clips: Vec<Vec<SpikePlane>> = (0..LANES)
            .map(|b| common::random_clip(8, 16, 16, 4, 1.0 - s, 0x1700 + (si * LANES + b) as u64))
            .collect();
        let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
        let t0 = Instant::now();
        let frames = LaneFrame::pack_clips(&refs).unwrap();
        let mut bank = LaneBank::zeros(16 * 16, 24, LANES);
        core.run_layer_lanes(&layer, &frames, &mut bank).unwrap();
        let clips_s = LANES as f64 / t0.elapsed().as_secs_f64();
        println!("{:>9.0}% | {:>14.1}", s * 100.0, clips_s);
        common::emit("fig17_batched_clips_per_s", s, clips_s);
    }
}
