//! Fig. 17 — peak performance (GOPS) and energy efficiency (TOPS/W) as
//! a function of input sparsity and weight precision.
//!
//! Paper claims: ~2x throughput moving 8-bit -> 4-bit at equal
//! sparsity, and ~2x moving 80 % -> 95 % sparsity at 4-bit.

mod common;

use spidr::energy::calibration::measure;
use spidr::energy::model::Corner;
use spidr::quant::{Precision, ALL_PRECISIONS};

fn main() {
    common::header("Fig. 17", "GOPS & TOPS/W vs sparsity x precision (50 MHz / 0.9 V)");
    let sparsities = [0.60, 0.70, 0.80, 0.85, 0.90, 0.95];

    println!(
        "{:>10} | {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8}",
        "sparsity", "4b GOPS", "6b GOPS", "8b GOPS", "4b T/W", "6b T/W", "8b T/W"
    );
    let mut table = Vec::new();
    for &s in &sparsities {
        let pts: Vec<_> = ALL_PRECISIONS
            .iter()
            .map(|&p| measure(p, Corner::LOW, s))
            .collect();
        println!(
            "{:>9.0}% | {:>8.2} {:>8.2} {:>8.2} | {:>8.2} {:>8.2} {:>8.2}",
            s * 100.0,
            pts[0].gops, pts[1].gops, pts[2].gops,
            pts[0].tops_per_watt, pts[1].tops_per_watt, pts[2].tops_per_watt
        );
        for pt in &pts {
            common::emit(&format!("fig17_gops_w{}", pt.weight_bits), s, pt.gops);
            common::emit(&format!("fig17_topsw_w{}", pt.weight_bits), s, pt.tops_per_watt);
        }
        table.push(pts);
    }

    let p4_95 = &table[5][0];
    let p8_95 = &table[5][2];
    let p4_80 = &table[2][0];
    println!("\n8b->4b @95 %: {:.2}x throughput (paper ~2x)", p4_95.gops / p8_95.gops);
    println!("80->95 % @4b: {:.2}x throughput (paper ~2x)", p4_95.gops / p4_80.gops);

    let hi4 = measure(Precision::W4V7, Corner::HIGH, 0.95);
    println!("peak: {:.2} GOPS @150 MHz/1 V, 4-bit, 95 % (paper: 73.59)", hi4.gops);
}
