//! Fig. 16 — accuracy (gesture) / AEE (flow) vs energy at different
//! weight precisions (50 MHz / 0.9 V).
//!
//! The task metrics come from the build-time evaluation
//! (`artifacts/fig16_eval.txt`, written by `make artifacts`); the
//! energy per inference comes from the cycle simulator running the
//! same trained networks on synthetic clips. "Since this is a digital
//! CIM design, there is no loss in accuracy at hardware
//! implementation" — our equivalent statement is the bit-exactness of
//! the simulator against the quantized model (checked in tests).

mod common;

use std::collections::HashMap;

use spidr::coordinator::NetworkCompiler;
use spidr::dvs::flow_scene::{make_flow_scene, FlowSceneConfig};
use spidr::dvs::gesture::{make_gesture, GestureConfig};
use spidr::energy::model::Corner;
use spidr::quant::Precision;
use spidr::sim::SimConfig;
use spidr::snn::network::{flow_network, gesture_network};
use spidr::snn::WeightBundle;

fn load_metrics() -> Option<HashMap<(String, String), f64>> {
    let text = std::fs::read_to_string("artifacts/fig16_eval.txt").ok()?;
    let mut out = HashMap::new();
    for line in text.lines() {
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() == 4 {
            if let Ok(v) = parts[3].parse::<f64>() {
                out.insert((parts[0].to_string(), parts[2].to_string()), v);
            }
        }
    }
    Some(out)
}

fn main() {
    common::header("Fig. 16", "accuracy / AEE and energy vs weight precision");
    let Some(metrics) = load_metrics() else {
        println!("SKIPPED: artifacts/fig16_eval.txt missing — run `make artifacts`");
        return;
    };

    for task in ["gesture", "flow"] {
        let metric_name = if task == "gesture" { "accuracy" } else { "AEE (px/step)" };
        println!("\n{task} — {metric_name} + simulated energy/inference:");
        println!("{:>7} {:>10} {:>14} {:>12}", "prec", "metric", "uJ/inference", "TOPS/W");
        if let Some(fl) = metrics.get(&(task.to_string(), "float".to_string())) {
            println!("{:>7} {:>10.4} {:>14} {:>12}", "float", fl, "-", "-");
        }
        for wb in [4u32, 6, 8] {
            let key = (task.to_string(), wb.to_string());
            let Some(&m) = metrics.get(&key) else { continue };
            let p = Precision::from_weight_bits(wb).unwrap();
            let bundle = match WeightBundle::load(format!("artifacts/weights/{task}_w{wb}.swb")) {
                Ok(b) => b,
                Err(e) => {
                    println!("{:>7} {:>10.4}   (no bundle: {e})", format!("{wb}b"), m);
                    continue;
                }
            };
            // Energy on a small synthetic clip at the trained geometry.
            let (net, frames) = if task == "gesture" {
                let net = gesture_network(&bundle, p, 64, 64, 10).unwrap();
                let clip = make_gesture(3, 55, &GestureConfig {
                    height: 64, width: 64, timesteps: 10, noise_rate: 0.008 });
                (net, clip.frames)
            } else {
                let net = flow_network(&bundle, p, 24, 32, 10).unwrap();
                let scene = make_flow_scene(55, &FlowSceneConfig {
                    height: 24, width: 32, timesteps: 10, ..Default::default() });
                (net, scene.frames)
            };
            let compiled = NetworkCompiler::compile(net, SimConfig::timing_only(p)).unwrap();
            let mut state = compiled.network.init_state().unwrap();
            let report = compiled.run_clip(&frames, &mut state).unwrap();
            let uj = report.total.total_energy_pj(Corner::LOW) / 1e6;
            let tw = report.total.tops_per_watt(Corner::LOW);
            println!("{:>7} {:>10.4} {:>14.2} {:>12.2}", format!("{wb}b"), m, uj, tw);
            common::emit(&format!("fig16_{task}_metric"), wb as f64, m);
            common::emit(&format!("fig16_{task}_uj"), wb as f64, uj);
        }
    }
    println!("\npaper: lower precision trades task metric for proportionally lower energy");
}
