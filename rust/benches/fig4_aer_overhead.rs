//! Fig. 4 — overhead of AER input representation vs raw bitmaps as a
//! function of input sparsity, for the example spiking-conv layer
//! input (2 x 128 x 128 → 15-bit addresses + 4-bit protocol overhead).
//!
//! The paper's claim: AER pays off only above ~94.7 % sparsity; below
//! that the explicit addresses cost more than the raw bitmap. Both the
//! bit-traffic crossover and the input-path energy crossover are
//! reported.

mod common;

use spidr::baselines::{aer_input_cost, raw_input_cost};
use spidr::energy::model::EnergyParams;

fn main() {
    common::header(
        "Fig. 4",
        "AER vs raw-bitmap input cost across sparsity (2x128x128 layer input)",
    );
    let e = EnergyParams::default();
    let sparsities = [
        0.50, 0.60, 0.70, 0.80, 0.85, 0.90, 0.92, 0.94, 0.945, 0.947, 0.95,
        0.96, 0.97, 0.98, 0.99, 0.995,
    ];
    println!(
        "{:>9} {:>12} {:>12} {:>9} | {:>12} {:>12} {:>9}",
        "sparsity", "AER kbit", "raw kbit", "ratio", "AER nJ", "raw nJ", "ratio"
    );
    let mut bit_crossover = None;
    let mut prev_ratio = f64::INFINITY;
    for &s in &sparsities {
        let plane = common::random_plane(2, 128, 128, 1.0 - s, 0x41);
        let a = aer_input_cost(&plane, &e);
        let r = raw_input_cost(&plane, &e);
        let bit_ratio = a.bits as f64 / r.bits as f64;
        let e_ratio = a.energy_pj / r.energy_pj;
        println!(
            "{:>8.1}% {:>12.1} {:>12.1} {:>9.3} | {:>12.2} {:>12.2} {:>9.3}",
            s * 100.0,
            a.bits as f64 / 1e3,
            r.bits as f64 / 1e3,
            bit_ratio,
            a.energy_pj / 1e3,
            r.energy_pj / 1e3,
            e_ratio
        );
        common::emit("fig4_bits_ratio", s, bit_ratio);
        common::emit("fig4_energy_ratio", s, e_ratio);
        if prev_ratio > 1.0 && bit_ratio <= 1.0 && bit_crossover.is_none() {
            bit_crossover = Some(s);
        }
        prev_ratio = bit_ratio;
    }
    println!();
    match bit_crossover {
        Some(s) => println!(
            "bit-traffic crossover at ~{:.1} % sparsity (paper: 94.7 %)",
            s * 100.0
        ),
        None => println!("no crossover found in sweep range"),
    }
}
