//! Single-clip latency bench: staged layer-group pipeline vs the
//! sequential reference executor on the same multi-layer clip
//! (DESIGN.md §Pipeline).
//!
//! Series (`DATA` lines + JSONL rows appended to `BENCH_pipeline.json`):
//!
//! * `clip_latency_sequential_us` — `ReferenceEngine` (whole-network
//!   `Network::step` per timestep), the baseline; x = 1.
//! * `clip_latency_pipelined_us`  — `PipelinedEngine` latency vs
//!   stage count.
//! * `clip_latency_speedup`      — sequential / pipelined vs stage
//!   count (the acceptance series: expected ≥ 1.5× once ≥ 3 stages
//!   carry comparable cost — latency approaches the *max* stage cost
//!   instead of the sum).
//! * `pipeline_stage_occupancy`  — mean stage occupancy at each
//!   stage count (how well the stages overlap).
//!
//! Outputs are asserted bit-identical between the two engines on
//! every shape — this bench doubles as an end-to-end equivalence
//! smoke on a real workload.

mod common;

use spidr::coordinator::{Engine, PipelineConfig, PipelinedEngine, ReferenceEngine};
use spidr::snn::network::demo_pipeline_network;
use spidr::snn::spikes::SpikePlane;

const TIMESTEPS: usize = 12;
const REPS: usize = 5;

/// Best-of-N single-clip latency in microseconds.
fn best_latency_us<E: Engine>(engine: &mut E, clip: &[SpikePlane]) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let (_, secs) = common::timed(|| engine.infer(clip).unwrap());
        best = best.min(secs * 1e6);
    }
    best
}

fn main() {
    common::header(
        "pipeline",
        "single-clip latency: staged layer-group pipeline vs sequential",
    );
    let net = demo_pipeline_network(TIMESTEPS).expect("demo workload");
    let clip = common::random_clip(2, 24, 24, TIMESTEPS, 0.2, 42);

    let mut seq = ReferenceEngine::new(net.clone()).expect("reference engine");
    let want = seq.infer(&clip).expect("reference clip");
    let seq_us = best_latency_us(&mut seq, &clip);
    println!("sequential: {seq_us:.0} us/clip ({TIMESTEPS} steps, 5 stateful layers)");
    common::emit("clip_latency_sequential_us", 1.0, seq_us);

    for stages in [2usize, 3, 4, 5] {
        let mut pipe = PipelinedEngine::new(net.clone(), PipelineConfig::with_stages(stages))
            .expect("pipelined engine");
        let got = pipe.infer(&clip).expect("pipelined clip");
        assert_eq!(got, want, "pipelined output diverged at {stages} stages");
        let pipe_us = best_latency_us(&mut pipe, &clip);
        let speedup = seq_us / pipe_us;
        let occupancy = pipe.stage_metrics().iter().map(|s| s.occupancy()).sum::<f64>()
            / pipe.stage_metrics().len() as f64;
        println!(
            "pipelined x{}: {pipe_us:.0} us/clip, speedup {speedup:.2}, occupancy {:.0}%",
            pipe.groups().len(),
            occupancy * 100.0
        );
        common::emit("clip_latency_pipelined_us", stages as f64, pipe_us);
        common::emit("clip_latency_speedup", stages as f64, speedup);
        common::emit("pipeline_stage_occupancy", stages as f64, occupancy);
    }
}
