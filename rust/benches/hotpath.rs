//! Hot-path microbenchmarks (the §Perf harness): wall-clock throughput
//! of the simulator's inner loops, used to drive the optimization pass
//! recorded in EXPERIMENTS.md §Perf.

mod common;

use std::time::Instant;

use spidr::quant::{Overflow, Precision};
use spidr::sim::compute_macro::ComputeMacro;
use spidr::sim::config::SimConfig;
use spidr::sim::core::SpidrCore;
use spidr::sim::ifspad::IfSpad;
use spidr::sim::s2a::{run_tile, S2aOptions};
use spidr::snn::layer::{Layer, NeuronConfig};
use spidr::snn::tensor::Mat;

fn bench_s2a(density: f64) -> (f64, u64) {
    let mut rng = spidr::prop::SplitMix64::new(0xBE);
    let mut spad = IfSpad::new();
    spad.clear(128, 16);
    for y in 0..128 {
        for x in 0..16 {
            if rng.chance(density) {
                spad.write(y, x, true);
            }
        }
    }
    let ready: Vec<u64> = (1..=128).collect();
    let mut w = Mat::zeros(128, 12);
    for f in 0..128 {
        for k in 0..12 {
            w.set(f, k, ((f * k) % 15) as i32 - 7);
        }
    }
    let mut cm = ComputeMacro::new(w, 7, Overflow::Wrap, true);
    let opts = S2aOptions::default();
    let iters = 2000;
    let t0 = Instant::now();
    let mut ops = 0;
    for _ in 0..iters {
        cm.reset_vmems();
        let st = run_tile(&spad, &ready, &mut cm, &opts);
        ops += st.macro_ops;
    }
    let dt = t0.elapsed().as_secs_f64();
    (ops as f64 / dt, ops / iters)
}

fn bench_layer(functional: bool) -> f64 {
    let layer = Layer::conv(
        (32, 24, 32),
        32,
        3,
        3,
        1,
        1,
        Mat::zeros(288, 32),
        NeuronConfig { theta: 16, leak: 2, leaky: true, ..Default::default() },
        false,
    )
    .unwrap();
    let frames = common::random_clip(32, 24, 32, 4, 0.25, 0x99);
    let mut cfg = SimConfig::timing_only(Precision::W4V7);
    cfg.functional = functional;
    let core = SpidrCore::new(cfg);
    let iters = 3;
    let t0 = Instant::now();
    for _ in 0..iters {
        let mut state = Mat::zeros(24 * 32, 32);
        core.run_layer(&layer, &frames, &mut state).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    let synops = layer.dense_synops() * 4;
    synops as f64 / dt
}

/// Multi-pass Mode-1 shape: 72 output channels at 4-bit map 36 channels
/// per pass (3 pipelines × 12 neurons/row) → 6 channel groups over 2
/// passes, all replaying each tile's cached spike stream (§Perf — the
/// tile-stream cache's best case: loader + S2A host work drops by
/// ~passes × pipelines).
fn bench_layer_multipass(functional: bool) -> f64 {
    let layer = Layer::conv(
        (16, 16, 16),
        72,
        3,
        3,
        1,
        1,
        Mat::zeros(144, 72),
        NeuronConfig { theta: 16, leak: 2, leaky: true, ..Default::default() },
        false,
    )
    .unwrap();
    let frames = common::random_clip(16, 16, 16, 4, 0.25, 0x5A);
    let mut cfg = SimConfig::timing_only(Precision::W4V7);
    cfg.functional = functional;
    let core = SpidrCore::new(cfg);
    let iters = 5;
    let t0 = Instant::now();
    for _ in 0..iters {
        let mut state = Mat::zeros(16 * 16, 72);
        core.run_layer(&layer, &frames, &mut state).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    let synops = layer.dense_synops() * 4;
    synops as f64 / dt
}

fn main() {
    common::header("hotpath", "simulator wall-clock throughput (perf pass harness)");

    for &d in &[0.05f64, 0.25] {
        let (ops_s, ops_tile) = bench_s2a(d);
        println!(
            "S2A+macro tile @{:>4.0}% density: {:>10.2} M macro-ops/s wall ({} ops/tile)",
            d * 100.0,
            ops_s / 1e6,
            ops_tile
        );
        common::emit("hotpath_s2a_mops", d, ops_s / 1e6);
    }

    for functional in [true, false] {
        let ops_s = bench_layer(functional);
        println!(
            "run_layer (flow-like conv, {} ): {:>8.2} M dense-synops/s wall",
            if functional { "functional " } else { "timing-only" },
            ops_s / 1e6
        );
        common::emit(
            if functional { "hotpath_layer_func" } else { "hotpath_layer_timing" },
            0.0,
            ops_s / 1e6,
        );
    }

    for functional in [true, false] {
        let ops_s = bench_layer_multipass(functional);
        println!(
            "run_layer (multi-pass conv, {} ): {:>8.2} M dense-synops/s wall",
            if functional { "functional " } else { "timing-only" },
            ops_s / 1e6,
        );
        common::emit(
            if functional {
                "hotpath_layer_multipass_func"
            } else {
                "hotpath_layer_multipass_timing"
            },
            0.0,
            ops_s / 1e6,
        );
    }
}
