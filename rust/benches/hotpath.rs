//! Hot-path microbenchmarks (the §Perf harness): wall-clock throughput
//! of the simulator's inner loops, used to drive the optimization pass
//! recorded in EXPERIMENTS.md §Perf.

mod common;

use std::time::Instant;

use spidr::quant::{Overflow, Precision};
use spidr::sim::compute_macro::ComputeMacro;
use spidr::sim::config::SimConfig;
use spidr::sim::core::{LaneBank, SpidrCore};
use spidr::sim::ifspad::IfSpad;
use spidr::sim::s2a::{run_tile, S2aOptions};
use spidr::snn::layer::{Layer, NeuronConfig};
use spidr::snn::spikes::{LaneFrame, SpikePlane};
use spidr::snn::tensor::Mat;

fn bench_s2a(density: f64) -> (f64, u64) {
    let mut rng = spidr::prop::SplitMix64::new(0xBE);
    let mut spad = IfSpad::new();
    spad.clear(128, 16);
    for y in 0..128 {
        for x in 0..16 {
            if rng.chance(density) {
                spad.write(y, x, true);
            }
        }
    }
    let ready: Vec<u64> = (1..=128).collect();
    let mut w = Mat::zeros(128, 12);
    for f in 0..128 {
        for k in 0..12 {
            w.set(f, k, ((f * k) % 15) as i32 - 7);
        }
    }
    let mut cm = ComputeMacro::new(w, 7, Overflow::Wrap, true);
    let opts = S2aOptions::default();
    let iters = 2000;
    let t0 = Instant::now();
    let mut ops = 0;
    for _ in 0..iters {
        cm.reset_vmems();
        let st = run_tile(&spad, &ready, &mut cm, &opts);
        ops += st.macro_ops;
    }
    let dt = t0.elapsed().as_secs_f64();
    (ops as f64 / dt, ops / iters)
}

fn bench_layer(functional: bool) -> f64 {
    let layer = Layer::conv(
        (32, 24, 32),
        32,
        3,
        3,
        1,
        1,
        Mat::zeros(288, 32),
        NeuronConfig { theta: 16, leak: 2, leaky: true, ..Default::default() },
        false,
    )
    .unwrap();
    let frames = common::random_clip(32, 24, 32, 4, 0.25, 0x99);
    let mut cfg = SimConfig::timing_only(Precision::W4V7);
    cfg.functional = functional;
    let core = SpidrCore::new(cfg);
    let iters = 3;
    let t0 = Instant::now();
    for _ in 0..iters {
        let mut state = Mat::zeros(24 * 32, 32);
        core.run_layer(&layer, &frames, &mut state).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    let synops = layer.dense_synops() * 4;
    synops as f64 / dt
}

/// Multi-pass Mode-1 shape: 72 output channels at 4-bit map 36 channels
/// per pass (3 pipelines × 12 neurons/row) → 6 channel groups over 2
/// passes, all replaying each tile's cached spike stream (§Perf — the
/// tile-stream cache's best case: loader + S2A host work drops by
/// ~passes × pipelines).
fn bench_layer_multipass(functional: bool) -> f64 {
    let layer = Layer::conv(
        (16, 16, 16),
        72,
        3,
        3,
        1,
        1,
        Mat::zeros(144, 72),
        NeuronConfig { theta: 16, leak: 2, leaky: true, ..Default::default() },
        false,
    )
    .unwrap();
    let frames = common::random_clip(16, 16, 16, 4, 0.25, 0x5A);
    let mut cfg = SimConfig::timing_only(Precision::W4V7);
    cfg.functional = functional;
    let core = SpidrCore::new(cfg);
    let iters = 5;
    let t0 = Instant::now();
    for _ in 0..iters {
        let mut state = Mat::zeros(16 * 16, 72);
        core.run_layer(&layer, &frames, &mut state).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    let synops = layer.dense_synops() * 4;
    synops as f64 / dt
}

/// Batch-parallel bit-plane datapath (§Perf): 64 clips packed into
/// `u64` spike lanes and swept through the CIM rows once, against 64
/// per-clip `run_layer` calls of the same workload. The per-clip path
/// pays the cycle-accurate loader/S2A/FIFO machinery once per clip;
/// the batched path pays one union extraction per batch, so the gap
/// widens with sparsity. Per-lane bit-exactness is asserted inline.
fn bench_batched(density: f64) -> (f64, f64) {
    const LANES: usize = 64;
    let layer = Layer::conv(
        (16, 16, 16),
        32,
        3,
        3,
        1,
        1,
        Mat::zeros(144, 32),
        NeuronConfig { theta: 16, leak: 2, leaky: true, ..Default::default() },
        false,
    )
    .unwrap();
    let clips: Vec<Vec<SpikePlane>> = (0..LANES)
        .map(|b| common::random_clip(16, 16, 16, 4, density, 0x7000 + b as u64))
        .collect();
    let core = SpidrCore::new(SimConfig::default());

    // per-clip hot path: one cycle-accurate run_layer per clip
    let t0 = Instant::now();
    let mut per_clip_states = Vec::with_capacity(LANES);
    for clip in &clips {
        let mut state = Mat::zeros(16 * 16, 32);
        core.run_layer(&layer, clip, &mut state).unwrap();
        per_clip_states.push(state);
    }
    let t_clip = t0.elapsed().as_secs_f64();

    // batched lane path; packing is part of the serving cost, so it
    // sits inside the timed region
    let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
    let t0 = Instant::now();
    let frames = LaneFrame::pack_clips(&refs).unwrap();
    let mut bank = LaneBank::zeros(16 * 16, 32, LANES);
    core.run_layer_lanes(&layer, &frames, &mut bank).unwrap();
    let t_batch = t0.elapsed().as_secs_f64();

    for (b, state) in per_clip_states.iter().enumerate() {
        assert_eq!(
            bank.lane_mat(b).as_slice(),
            state.as_slice(),
            "lane {b} diverged from the per-clip hot path"
        );
    }
    (LANES as f64 / t_batch, t_clip / t_batch)
}

fn main() {
    common::header("hotpath", "simulator wall-clock throughput (perf pass harness)");

    for &d in &[0.05f64, 0.25] {
        let (ops_s, ops_tile) = bench_s2a(d);
        println!(
            "S2A+macro tile @{:>4.0}% density: {:>10.2} M macro-ops/s wall ({} ops/tile)",
            d * 100.0,
            ops_s / 1e6,
            ops_tile
        );
        common::emit("hotpath_s2a_mops", d, ops_s / 1e6);
    }

    for functional in [true, false] {
        let ops_s = bench_layer(functional);
        println!(
            "run_layer (flow-like conv, {} ): {:>8.2} M dense-synops/s wall",
            if functional { "functional " } else { "timing-only" },
            ops_s / 1e6
        );
        common::emit(
            if functional { "hotpath_layer_func" } else { "hotpath_layer_timing" },
            0.0,
            ops_s / 1e6,
        );
    }

    for functional in [true, false] {
        let ops_s = bench_layer_multipass(functional);
        println!(
            "run_layer (multi-pass conv, {} ): {:>8.2} M dense-synops/s wall",
            if functional { "functional " } else { "timing-only" },
            ops_s / 1e6,
        );
        common::emit(
            if functional {
                "hotpath_layer_multipass_func"
            } else {
                "hotpath_layer_multipass_timing"
            },
            0.0,
            ops_s / 1e6,
        );
    }

    for &sparsity in &[0.75f64, 0.95] {
        let (clips_s, speedup) = bench_batched(1.0 - sparsity);
        println!(
            "batched 64-lane conv @{:>3.0}% sparsity: {:>9.1} clips/s wall ({:>5.2}x vs per-clip)",
            sparsity * 100.0,
            clips_s,
            speedup
        );
        common::emit("hotpath_batched_clips_per_s", sparsity, clips_s);
        common::emit("hotpath_batched_speedup", sparsity, speedup);
    }
}
